//! Little-endian binary IO helpers for the on-disk formats
//! (`.rdat` datasets, `.rlsh` indexes), plus CRC-accumulating stream
//! wrappers for the v3 per-section checksums.

use std::io::{self, Read, Write};

use anyhow::Result;

use super::crc32::Crc32;

/// A `Write` adapter that CRC32s everything written through it. Call
/// [`HashingWriter::emit_section_crc`] at a section boundary to append
/// the digest of the bytes since the previous boundary; the 4 digest
/// bytes bypass the hash, so reader and writer stay in lockstep.
pub struct HashingWriter<W: Write> {
    inner: W,
    crc: Crc32,
}

impl<W: Write> HashingWriter<W> {
    pub fn new(inner: W) -> Self {
        Self { inner, crc: Crc32::new() }
    }

    /// Append the running section digest (little-endian, unhashed) and
    /// reset the accumulator for the next section.
    pub fn emit_section_crc(&mut self) -> Result<()> {
        let digest = self.crc.finalize();
        self.crc.reset();
        self.inner.write_all(&digest.to_le_bytes())?;
        Ok(())
    }

    pub fn get_ref(&self) -> &W {
        &self.inner
    }

    pub fn get_mut(&mut self) -> &mut W {
        &mut self.inner
    }
}

impl<W: Write> Write for HashingWriter<W> {
    // staticcheck: allow(panic-reach, "n <= buf.len() by the io::Write contract of the inner writer, so buf[..n] is in bounds")
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.crc.update(&buf[..n]);
        Ok(n)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// The read-side twin of [`HashingWriter`]: CRC32s everything read
/// through it, and [`HashingReader::verify_section_crc`] consumes the
/// stored digest (unhashed) and compares it against the accumulator.
pub struct HashingReader<R: Read> {
    inner: R,
    crc: Crc32,
}

impl<R: Read> HashingReader<R> {
    pub fn new(inner: R) -> Self {
        Self { inner, crc: Crc32::new() }
    }

    /// Read the 4-byte stored digest at a section boundary, compare it to
    /// the digest of the bytes read since the previous boundary, and
    /// reset the accumulator. `section` names the section in the error.
    pub fn verify_section_crc(&mut self, section: &str) -> Result<()> {
        let computed = self.crc.finalize();
        self.crc.reset();
        let mut b = [0u8; 4];
        self.inner
            .read_exact(&mut b)
            .map_err(|e| anyhow::anyhow!("{section} section: reading checksum: {e}"))?;
        let stored = u32::from_le_bytes(b);
        anyhow::ensure!(
            computed == stored,
            "{section} section: checksum mismatch (stored {stored:08x}, computed {computed:08x})"
        );
        Ok(())
    }

    /// Discard the accumulated digest (used for formats predating the
    /// checksum trailers, where the hash is never verified).
    pub fn reset_crc(&mut self) {
        self.crc.reset();
    }
}

impl<R: Read> Read for HashingReader<R> {
    // staticcheck: allow(panic-reach, "Read::read returns n <= buf.len() by contract")
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.crc.update(&buf[..n]);
        Ok(n)
    }
}

pub fn write_u8(w: &mut impl Write, v: u8) -> Result<()> {
    w.write_all(&[v])?;
    Ok(())
}

pub fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn write_f32(w: &mut impl Write, v: f32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn write_u32s(w: &mut impl Write, vs: &[u32]) -> Result<()> {
    write_u64(w, vs.len() as u64)?;
    for &v in vs {
        write_u32(w, v)?;
    }
    Ok(())
}

pub fn write_u64s(w: &mut impl Write, vs: &[u64]) -> Result<()> {
    write_u64(w, vs.len() as u64)?;
    for &v in vs {
        write_u64(w, v)?;
    }
    Ok(())
}

pub fn write_f32s(w: &mut impl Write, vs: &[f32]) -> Result<()> {
    write_u64(w, vs.len() as u64)?;
    for &v in vs {
        write_f32(w, v)?;
    }
    Ok(())
}

// staticcheck: allow(panic-reach, "b is a [u8; 1] filled by read_exact; index 0 is in bounds by construction")
pub fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

pub fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub fn read_f32(r: &mut impl Read) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

/// Bounded length read: fails fast on corrupt headers instead of OOMing.
fn read_len(r: &mut impl Read) -> Result<usize> {
    let len = read_u64(r)?;
    anyhow::ensure!(len <= (1 << 34), "implausible length {len} (corrupt file?)");
    Ok(len as usize)
}

pub fn read_u32s(r: &mut impl Read) -> Result<Vec<u32>> {
    let len = read_len(r)?;
    (0..len).map(|_| read_u32(r)).collect()
}

pub fn read_u64s(r: &mut impl Read) -> Result<Vec<u64>> {
    let len = read_len(r)?;
    (0..len).map(|_| read_u64(r)).collect()
}

pub fn read_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let len = read_len(r)?;
    (0..len).map(|_| read_f32(r)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_scalars_and_vectors() {
        let mut buf = Vec::new();
        write_u8(&mut buf, 7).unwrap();
        write_u32(&mut buf, 0xDEAD_BEEF).unwrap();
        write_u64(&mut buf, u64::MAX - 1).unwrap();
        write_f32(&mut buf, -0.5).unwrap();
        write_u32s(&mut buf, &[1, 2, 3]).unwrap();
        write_u64s(&mut buf, &[9, 8]).unwrap();
        write_f32s(&mut buf, &[0.25, -1.0]).unwrap();
        let mut r = buf.as_slice();
        assert_eq!(read_u8(&mut r).unwrap(), 7);
        assert_eq!(read_u32(&mut r).unwrap(), 0xDEAD_BEEF);
        assert_eq!(read_u64(&mut r).unwrap(), u64::MAX - 1);
        assert_eq!(read_f32(&mut r).unwrap(), -0.5);
        assert_eq!(read_u32s(&mut r).unwrap(), vec![1, 2, 3]);
        assert_eq!(read_u64s(&mut r).unwrap(), vec![9, 8]);
        assert_eq!(read_f32s(&mut r).unwrap(), vec![0.25, -1.0]);
        assert!(r.is_empty());
    }

    #[test]
    fn rejects_implausible_lengths() {
        let mut buf = Vec::new();
        write_u64(&mut buf, u64::MAX).unwrap();
        assert!(read_u32s(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn hashing_streams_round_trip_sections() {
        let mut w = HashingWriter::new(Vec::new());
        write_u32(&mut w, 0xFEED).unwrap();
        write_f32s(&mut w, &[1.5, -2.0]).unwrap();
        w.emit_section_crc().unwrap();
        write_u64(&mut w, 99).unwrap();
        w.emit_section_crc().unwrap();
        let bytes = std::mem::take(w.get_mut());

        let mut r = HashingReader::new(bytes.as_slice());
        assert_eq!(read_u32(&mut r).unwrap(), 0xFEED);
        assert_eq!(read_f32s(&mut r).unwrap(), vec![1.5, -2.0]);
        r.verify_section_crc("first").unwrap();
        assert_eq!(read_u64(&mut r).unwrap(), 99);
        r.verify_section_crc("second").unwrap();
    }

    #[test]
    fn hashing_reader_flags_corrupt_section() {
        let mut w = HashingWriter::new(Vec::new());
        write_u64(&mut w, 0xAB).unwrap();
        w.emit_section_crc().unwrap();
        let mut bytes = std::mem::take(w.get_mut());
        bytes[2] ^= 0x10;

        let mut r = HashingReader::new(bytes.as_slice());
        read_u64(&mut r).unwrap();
        let err = r.verify_section_crc("params").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("params"), "unexpected error: {msg}");
        assert!(msg.contains("checksum mismatch"), "unexpected error: {msg}");
    }
}
