//! Vendored CRC32 (IEEE 802.3, polynomial `0xEDB8_8320`), the checksum
//! behind the `.rlsh` v3 per-section trailers. Table-driven, with the
//! table built by a `const fn` at compile time — no external deps, per
//! the in-tree substrate discipline (see [`crate::util`]).

/// Reflected-polynomial lookup table, one entry per input byte value.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// Incremental CRC32 state. Feed bytes with [`Crc32::update`], read the
/// digest with [`Crc32::finalize`] (non-consuming, so a hashing stream
/// can emit a section digest and keep going after [`Crc32::reset`]).
#[derive(Debug, Clone, Copy)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    // staticcheck: allow(panic-reach, "the table index is masked with & 0xFF and TABLE has 256 entries")
    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = TABLE[((self.state ^ b as u32) & 0xFF) as usize] ^ (self.state >> 8);
        }
    }

    pub fn finalize(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }

    pub fn reset(&mut self) {
        self.state = 0xFFFF_FFFF;
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot digest of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_known_vectors() {
        // The standard CRC32 check value, plus edges.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn incremental_equals_one_shot_and_resets() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let mut c = Crc32::new();
        for chunk in data.chunks(7) {
            c.update(chunk);
        }
        assert_eq!(c.finalize(), crc32(data));
        c.reset();
        c.update(b"123456789");
        assert_eq!(c.finalize(), 0xCBF4_3926);
    }

    #[test]
    fn detects_single_bit_flips() {
        let mut data = b"section payload under test".to_vec();
        let clean = crc32(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                data[byte] ^= 1 << bit;
                assert_ne!(crc32(&data), clean, "flip at {byte}:{bit} undetected");
                data[byte] ^= 1 << bit;
            }
        }
    }
}
