//! Deterministic, fast hashing for in-memory maps (FxHash-style
//! multiply-rotate, as used by rustc). Two purposes:
//!
//! 1. **Reproducibility** — std's default `RandomState` seeds SipHash per
//!    process, so bucket iteration order (hence probe order within equal-
//!    rank groups) would differ run to run. Experiments must be replayable.
//! 2. **Speed** — the bucket tables sit on the probe hot path; FxHash is
//!    several times faster than SipHash on short keys.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash-style hasher: word-at-a-time multiply-xor-rotate.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    // staticcheck: allow(panic-reach, "chunks_exact(8) makes try_into::<[u8; 8]>() infallible and the tail copy is bounded by rem.len() < 8")
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(tail));
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }
}

/// Deterministic-hashing `HashMap` (insertion-independent iteration order
/// per identical key set).
pub type FxHashMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::BuildHasher;

    fn hash_of(v: u64) -> u64 {
        let mut h = FxHasher::default();
        h.write_u64(v);
        h.finish()
    }

    #[test]
    fn deterministic_across_instances() {
        assert_eq!(hash_of(42), hash_of(42));
        assert_ne!(hash_of(42), hash_of(43));
        let s = BuildHasherDefault::<FxHasher>::default();
        assert_eq!(s.hash_one("abc"), s.hash_one("abc"));
    }

    #[test]
    fn map_iteration_order_is_reproducible() {
        // The reproducibility contract: same keys inserted in the same
        // order ⇒ same iteration order, across map instances (and, unlike
        // RandomState, across process runs). Index builds are
        // deterministic, so this makes probe order replayable.
        let build = || -> Vec<u64> {
            let mut m: FxHashMap<u64, ()> = FxHashMap::default();
            for k in [1u64, 2, 3, 4, 5, 100, 999, 12345, 1 << 40] {
                m.insert(k, ());
            }
            m.keys().copied().collect()
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn spreads_sequential_keys() {
        // Counting distinct high bytes of hashes of 0..256 — a weak but
        // sufficient avalanche check for bucket indexing.
        let distinct: std::collections::HashSet<u8> =
            (0..256u64).map(|v| (hash_of(v) >> 56) as u8).collect();
        assert!(distinct.len() > 100, "poor spread: {}", distinct.len());
    }

    #[test]
    fn handles_unaligned_byte_tails() {
        let mut h1 = FxHasher::default();
        h1.write(b"hello");
        let mut h2 = FxHasher::default();
        h2.write(b"hellp");
        assert_ne!(h1.finish(), h2.finish());
    }
}
