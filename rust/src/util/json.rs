//! Minimal JSON: a recursive-descent parser (reads the AOT
//! `manifest.json`) and a writer (emits experiment results). Covers the
//! full JSON grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as usize)
            } else {
                None
            }
        })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialise (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Builder helpers for result emission.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: impl IntoIterator<Item = f64>) -> Json {
        Json::Arr(v.into_iter().map(Json::Num).collect())
    }

    pub fn arr_usize(v: impl IntoIterator<Item = usize>) -> Json {
        Json::Arr(v.into_iter().map(|x| Json::Num(x as f64)).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    // staticcheck: allow(panic-reach, "the while condition checks i < b.len() before the index")
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at offset {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at offset {}", other.map(|c| c as char), self.i),
        }
    }

    // staticcheck: allow(panic-reach, "i never exceeds b.len() and a full-range slice at i == len is valid")
    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i)
        }
    }

    // staticcheck: allow(panic-reach, "expect here is Parser::expect(u8) -> Result propagated with ?, not Option::expect - a lint name collision")
    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.i),
            }
        }
    }

    // staticcheck: allow(panic-reach, "expect here is Parser::expect(u8) -> Result propagated with ?, not Option::expect - a lint name collision")
    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.i),
            }
        }
    }

    // staticcheck: allow(panic-reach, "expect is the parser's own Result-returning method, and byte access is guarded by peek() bounds checks")
    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    // staticcheck: allow(panic-reach, "start <= i <= b.len() by construction, so the slice bounds are valid")
    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
            "format": "hlo-text", "item_block": 2048,
            "entries": [{"name": "hash_items_d300", "inputs": [{"shape": [2048, 300]}]}]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("format").unwrap().as_str(), Some("hlo-text"));
        assert_eq!(j.get("item_block").unwrap().as_usize(), Some(2048));
        let entries = j.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries[0].get("name").unwrap().as_str(), Some("hash_items_d300"));
        let shape = entries[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_usize(), Some(300));
    }

    #[test]
    fn round_trips() {
        let text = r#"{"a":[1,2.5,-3e2],"b":"x\"y","c":true,"d":null,"e":{}}"#;
        let j = Json::parse(text).unwrap();
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn string_escapes_round_trip() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse(r#""héllo ☃""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo ☃"));
    }

    #[test]
    fn builders_compose() {
        let j = Json::obj(vec![
            ("xs", Json::arr_usize([1, 2, 3])),
            ("ys", Json::arr_f64([0.5, 1.0])),
        ]);
        let s = j.to_string();
        assert!(s.contains("\"xs\":[1,2,3]"));
        assert!(s.contains("\"ys\":[0.5,1]"));
    }
}
