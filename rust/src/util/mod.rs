//! In-tree substrates. The build is fully offline (only the `xla` PJRT
//! bindings and `anyhow` are vendored), so the infrastructure a crates.io
//! project would pull in is implemented here from scratch:
//!
//! - [`rng`] — seeded xoshiro256++ PRNG with normal / log-normal / uniform
//!   sampling (replaces `rand` + `rand_distr`).
//! - [`par`] — scoped data-parallel helpers over `std::thread` (replaces
//!   `rayon` for this crate's embarrassingly parallel loops).
//! - [`json`] — minimal JSON parser/writer (replaces `serde_json`; parses
//!   the AOT `manifest.json`, writes experiment results).
//! - [`toml`] — minimal TOML-subset parser (replaces `toml` for the
//!   config system).

pub mod bytes;
pub mod crc32;
pub mod fxhash;
pub mod json;
pub mod par;
pub mod rng;
pub mod tmp;
pub mod toml;
