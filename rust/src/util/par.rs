//! Scoped data-parallel helpers over `std::thread` — the crate's rayon
//! replacement. All loops here are embarrassingly parallel over contiguous
//! index blocks, so static block partitioning is within a few percent of a
//! work-stealing pool at far less machinery.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count: `RANGELSH_THREADS` override, else available parallelism.
pub fn n_threads() -> usize {
    if let Ok(v) = std::env::var("RANGELSH_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map_or(4, |n| n.get())
}

/// Parallel map over `0..n`: returns `vec![f(0), f(1), ..., f(n-1)]`.
/// Falls back to serial for `n < 64` (cheap-per-item default).
pub fn par_map<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    par_map_cutoff(n, 64, f)
}

/// [`par_map`] with an explicit serial cutoff — use a small cutoff when
/// each item is expensive (e.g. a multi-ms index probe).
// staticcheck: allow(panic-reach, "scope joins every worker before the unwrap and each worker fills its whole block, so no slot is None")
pub fn par_map_cutoff<R, F>(n: usize, cutoff: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let threads = n_threads().min(n.max(1));
    if threads <= 1 || n < cutoff {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let chunk = n.div_ceil(threads);
    let slots = out.as_mut_slice();
    std::thread::scope(|scope| {
        for (t, block) in slots.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || {
                let base = t * chunk;
                for (i, slot) in block.iter_mut().enumerate() {
                    *slot = Some(f(base + i));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled every slot")).collect()
}

/// Parallel fold: map each index then combine with `merge` (associative).
pub fn par_fold<A, F, M>(n: usize, identity: impl Fn() -> A + Sync, f: F, merge: M) -> A
where
    A: Send,
    F: Fn(usize, &mut A) + Sync,
    M: Fn(A, A) -> A,
{
    let threads = n_threads().min(n.max(1));
    if threads <= 1 || n < 64 {
        let mut acc = identity();
        for i in 0..n {
            f(i, &mut acc);
        }
        return acc;
    }
    let chunk = n.div_ceil(threads);
    let mut partials: Vec<A> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            let identity = &identity;
            handles.push(scope.spawn(move || {
                let mut acc = identity();
                for i in lo..hi {
                    f(i, &mut acc);
                }
                acc
            }));
        }
        for h in handles {
            partials.push(h.join().expect("par_fold worker panicked"));
        }
    });
    let mut it = partials.into_iter();
    let first = it.next().expect("at least one partial");
    it.fold(first, merge)
}

/// Parallel for-each over mutable, disjoint row chunks of `data`
/// (`rows_per_item` elements each): `f(item_index, row_slice)`.
pub fn par_rows_mut<T, F>(data: &mut [T], rows_per_item: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(rows_per_item > 0);
    assert_eq!(data.len() % rows_per_item, 0);
    let n = data.len() / rows_per_item;
    let threads = n_threads().min(n.max(1));
    if threads <= 1 || n < 64 {
        for (i, row) in data.chunks_mut(rows_per_item).enumerate() {
            f(i, row);
        }
        return;
    }
    let chunk_items = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, block) in data.chunks_mut(chunk_items * rows_per_item).enumerate() {
            let f = &f;
            scope.spawn(move || {
                let base = t * chunk_items;
                for (i, row) in block.chunks_mut(rows_per_item).enumerate() {
                    f(base + i, row);
                }
            });
        }
    });
}

/// Progress-friendly atomic counter (used by long benches).
#[derive(Default)]
pub struct Counter(AtomicUsize);

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn add(&self, v: usize) -> usize {
        self.0.fetch_add(v, Ordering::Relaxed) + v
    }
    pub fn get(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let got = par_map(1000, |i| i * i);
        let want: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_map_small_n() {
        assert_eq!(par_map(3, |i| i + 1), vec![1, 2, 3]);
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn par_fold_sums() {
        let total = par_fold(
            10_000,
            || 0u64,
            |i, acc| *acc += i as u64,
            |a, b| a + b,
        );
        assert_eq!(total, (0..10_000u64).sum());
    }

    #[test]
    fn par_rows_mut_touches_every_row_once() {
        let mut data = vec![0u32; 500 * 4];
        par_rows_mut(&mut data, 4, |i, row| {
            for v in row.iter_mut() {
                *v += i as u32 + 1;
            }
        });
        for (i, row) in data.chunks(4).enumerate() {
            assert!(row.iter().all(|&v| v == i as u32 + 1), "row {i}");
        }
    }

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        let total = par_fold(
            100,
            || 0usize,
            |_, acc| {
                c.add(1);
                *acc += 1;
            },
            |a, b| a + b,
        );
        assert_eq!(total, 100);
        assert_eq!(c.get(), 100);
    }

    #[test]
    fn n_threads_is_positive() {
        assert!(n_threads() >= 1);
    }
}
