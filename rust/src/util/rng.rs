//! Seeded PRNG + distributions: xoshiro256++ core (Blackman & Vigna),
//! splitmix64 seeding, Box–Muller Gaussian, log-normal on top.
//!
//! Statistical quality matters here: the Gaussian feeds the sign-RP
//! projection panels (paper Eq. 4) and the synthetic norm distributions
//! (DESIGN.md §3); the tests below check moments and tail behaviour.

/// xoshiro256++ with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, gauss_spare: None }
    }

    #[inline]
    // staticcheck: allow(panic-reach, "state indices are constants into the fixed [u64; 4] xoshiro state")
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform01(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(hi > lo);
        lo + self.uniform01() * (hi - lo)
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free enough for
    /// non-crypto use via widening multiply).
    pub fn gen_index(&mut self, n: usize) -> usize {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.uniform01();
        let u2 = self.uniform01();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Log-normal: `exp(mu + sigma * Z)`.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fill a buffer with standard normal f32s.
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out {
            *v = self.normal_f32();
        }
    }

    /// A fresh generator derived from this one (for per-thread streams).
    pub fn split(&mut self) -> Rng {
        Rng::seed_from_u64(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        let mut c = Rng::seed_from_u64(8);
        let (va, vb, vc): (Vec<u64>, Vec<u64>, Vec<u64>) = (
            (0..16).map(|_| a.next_u64()).collect(),
            (0..16).map(|_| b.next_u64()).collect(),
            (0..16).map(|_| c.next_u64()).collect(),
        );
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform01_is_in_range_and_roughly_uniform() {
        let mut r = Rng::seed_from_u64(1);
        let n = 100_000;
        let mut sum = 0.0;
        let mut below_half = 0usize;
        for _ in 0..n {
            let u = r.uniform01();
            assert!((0.0..1.0).contains(&u));
            sum += u;
            if u < 0.5 {
                below_half += 1;
            }
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
        assert!((below_half as f64 / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(2);
        let n = 200_000;
        let (mut sum, mut sumsq, mut sum3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sumsq += z * z;
            sum3 += z * z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        let skew = sum3 / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        assert!(skew.abs() < 0.05, "skew {skew}");
    }

    #[test]
    fn normal_tails_exist() {
        // P(|Z| > 3) ~ 0.0027: in 100k draws expect ~270, demand > 50.
        let mut r = Rng::seed_from_u64(3);
        let tail = (0..100_000).filter(|_| r.normal().abs() > 3.0).count();
        assert!(tail > 50 && tail < 1000, "tail count {tail}");
    }

    #[test]
    fn lognormal_median_is_exp_mu() {
        let mut r = Rng::seed_from_u64(4);
        let mut v: Vec<f64> = (0..50_000).map(|_| r.lognormal(0.0, 0.35)).collect();
        v.sort_by(|a, b| a.total_cmp(b));
        let median = v[v.len() / 2];
        assert!((median - 1.0).abs() < 0.02, "median {median}");
        assert!(v.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn gen_index_covers_range() {
        let mut r = Rng::seed_from_u64(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.gen_index(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn split_streams_differ() {
        let mut r = Rng::seed_from_u64(6);
        let mut a = r.split();
        let mut b = r.split();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
