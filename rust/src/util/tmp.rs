//! Unique temp-file paths with drop cleanup (tempfile stand-in, offline
//! build). Used by IO tests and the CLI's scratch outputs.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique path under the system temp dir, removed (best-effort) on drop.
pub struct TempPath(PathBuf);

impl TempPath {
    pub fn new(tag: &str) -> Self {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "rangelsh-{}-{}-{}-{}",
            tag,
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map_or(0, |d| d.as_nanos() as u64),
            n
        ));
        Self(path)
    }

    pub fn path(&self) -> &Path {
        &self.0
    }
}

impl Drop for TempPath {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paths_are_unique() {
        let a = TempPath::new("t");
        let b = TempPath::new("t");
        assert_ne!(a.path(), b.path());
    }

    #[test]
    fn drop_removes_file() {
        let p = TempPath::new("drop");
        let path = p.path().to_path_buf();
        std::fs::write(&path, b"x").unwrap();
        assert!(path.exists());
        drop(p);
        assert!(!path.exists());
    }
}
