//! Minimal TOML-subset parser for the config system. Supports:
//! `[section]` headers, `key = value` with string / integer / float /
//! boolean / homogeneous scalar arrays, `#` comments, and blank lines.
//! That covers every config this repo ships (`configs/*.toml`).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed scalar or scalar array.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64_array(&self) -> Option<Vec<f64>> {
        match self {
            TomlValue::Arr(v) => v.iter().map(|x| x.as_f64()).collect(),
            _ => None,
        }
    }
}

/// section name → key → value. Keys before any section land in `""`.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<TomlDoc> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .with_context(|| format!("line {}: unterminated section header", lineno + 1))?
                .trim();
            if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                bail!("line {}: bad section name {:?}", lineno + 1, name);
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let value = parse_value(value.trim())
            .with_context(|| format!("line {}: bad value", lineno + 1))?;
        doc.get_mut(&section)
            .expect("section entry exists")
            .insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .context("unterminated string")?;
        if inner.contains('"') {
            bail!("embedded quote in string value");
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').context("unterminated array")?;
        let mut out = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            out.push(parse_value(part)?);
        }
        return Ok(TomlValue::Arr(out));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

/// Typed accessor helper with good error messages.
pub struct Section<'a> {
    pub name: &'a str,
    map: Option<&'a BTreeMap<String, TomlValue>>,
}

impl<'a> Section<'a> {
    pub fn of(doc: &'a TomlDoc, name: &'a str) -> Self {
        Self { name, map: doc.get(name) }
    }

    pub fn exists(&self) -> bool {
        self.map.is_some()
    }

    pub fn get(&self, key: &str) -> Option<&'a TomlValue> {
        self.map.and_then(|m| m.get(key))
    }

    pub fn require(&self, key: &str) -> Result<&'a TomlValue> {
        self.get(key)
            .with_context(|| format!("missing key {:?} in section [{}]", key, self.name))
    }

    pub fn str_req(&self, key: &str) -> Result<&'a str> {
        self.require(key)?
            .as_str()
            .with_context(|| format!("[{}] {key} must be a string", self.name))
    }

    pub fn usize_req(&self, key: &str) -> Result<usize> {
        self.require(key)?
            .as_usize()
            .with_context(|| format!("[{}] {key} must be a non-negative integer", self.name))
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_usize()
                .with_context(|| format!("[{}] {key} must be a non-negative integer", self.name)),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_u64()
                .with_context(|| format!("[{}] {key} must be a non-negative integer", self.name)),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_f64()
                .with_context(|| format!("[{}] {key} must be a number", self.name)),
        }
    }

    pub fn str_or(&self, key: &str, default: &'a str) -> Result<&'a str> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_str()
                .with_context(|| format!("[{}] {key} must be a string", self.name)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# experiment config
[dataset]
kind = "longtail_sift"   # like ImageNet SIFT
n_items = 200000
sigma = 0.35
correlated = true

[eval]
recall_targets = [0.5, 0.8, 0.9]
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(DOC).unwrap();
        let ds = Section::of(&doc, "dataset");
        assert_eq!(ds.str_req("kind").unwrap(), "longtail_sift");
        assert_eq!(ds.usize_req("n_items").unwrap(), 200_000);
        assert_eq!(ds.f64_or("sigma", 0.0).unwrap(), 0.35);
        assert_eq!(ds.get("correlated").unwrap().as_bool(), Some(true));
        let ev = Section::of(&doc, "eval");
        assert_eq!(
            ev.get("recall_targets").unwrap().as_f64_array().unwrap(),
            vec![0.5, 0.8, 0.9]
        );
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let doc = parse("# only a comment\n\nx = 1\n").unwrap();
        assert_eq!(doc[""]["x"], TomlValue::Int(1));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse(r##"name = "a#b""##).unwrap();
        assert_eq!(doc[""]["name"].as_str(), Some("a#b"));
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let doc = parse("[s]\n").unwrap();
        let s = Section::of(&doc, "s");
        assert_eq!(s.usize_or("absent", 7).unwrap(), 7);
        assert!(s.usize_req("absent").is_err());
    }

    #[test]
    fn missing_section_reports_cleanly() {
        let doc = parse("").unwrap();
        let s = Section::of(&doc, "nope");
        assert!(!s.exists());
        let err = s.str_req("k").unwrap_err();
        assert!(format!("{err:#}").contains("[nope]"));
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("just a token\n").is_err());
        assert!(parse("k = \"open\n").is_err());
        assert!(parse("k = [1, 2\n").is_err());
    }

    #[test]
    fn negative_and_float_values() {
        let doc = parse("a = -5\nb = -0.25\n").unwrap();
        assert_eq!(doc[""]["a"], TomlValue::Int(-5));
        assert_eq!(doc[""]["b"].as_f64(), Some(-0.25));
        assert_eq!(doc[""]["a"].as_usize(), None);
    }
}
