//! Minimal in-tree stand-in for the `anyhow` crate (offline build).
//!
//! Implements the subset the rangelsh crate uses: [`Error`] with a context
//! chain, [`Result`], the [`Context`] extension trait for `Result`/`Option`,
//! the `anyhow!` / `bail!` / `ensure!` macros, and [`Error::downcast_ref`]
//! for typed errors (the payload survives `.context(..)` wrapping, like
//! upstream; unlike upstream, only the root error is downcastable — context
//! values are stored as strings). Display semantics match upstream: `{}`
//! prints the outermost message, `{:#}` prints the whole chain joined with
//! `": "` (which is also the `Debug` rendering, so `unwrap()` failures show
//! the full story).

use std::any::Any;
use std::fmt;

/// An error: a chain of human-readable messages, outermost context first,
/// root cause last, optionally carrying the typed root error for
/// [`Error::downcast_ref`].
pub struct Error {
    chain: Vec<String>,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Build an error from a single message (no typed payload).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()], payload: None }
    }

    /// Build an error from a typed `std::error::Error`, keeping it
    /// available through [`Error::downcast_ref`]. Equivalent to the
    /// `From` conversion, spelled out for call sites that want to be
    /// explicit about preserving the type.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Self {
        Self::from(error)
    }

    /// Wrap with an outer context message (what `Context::context` does).
    /// The typed payload, if any, is preserved.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// The typed root error, when this `Error` was built from one of type
    /// `T` (via `From`/[`Error::new`]/`?`). Context wrapping does not
    /// erase it. `anyhow!`-style message errors return `None`.
    pub fn downcast_ref<T: 'static>(&self) -> Option<&T> {
        self.payload.as_ref()?.downcast_ref::<T>()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

// Like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that keeps this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain, payload: Some(Box::new(e)) }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` (any error convertible to [`Error`], including `Error` itself)
/// and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_outer_alternate_chain() {
        let e: Error = Error::from(io_err()).context("opening config");
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: no such file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert!(format!("{e:#}").contains("outer: no such file"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "flag")).unwrap_err();
        assert_eq!(format!("{e}"), "missing flag");
    }

    #[test]
    fn context_stacks_on_anyhow_results() {
        fn inner() -> Result<()> {
            bail!("root {}", 42)
        }
        let e = inner().context("mid").context("top").unwrap_err();
        assert_eq!(format!("{e:#}"), "top: mid: root 42");
    }

    #[test]
    fn downcast_ref_survives_context() {
        #[derive(Debug, PartialEq)]
        struct Typed(u32);
        impl fmt::Display for Typed {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "typed error {}", self.0)
            }
        }
        impl std::error::Error for Typed {}

        let e = Error::new(Typed(7)).context("outer");
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(7)));
        assert!(e.downcast_ref::<std::io::Error>().is_none());
        assert_eq!(format!("{e:#}"), "outer: typed error 7");

        // Message-only errors carry no payload.
        assert!(anyhow!("plain").downcast_ref::<Typed>().is_none());
    }

    #[test]
    fn ensure_formats_condition() {
        fn check(v: usize) -> Result<()> {
            ensure!(v < 10, "value {v} too large");
            ensure!(v != 3);
            Ok(())
        }
        assert!(check(1).is_ok());
        assert!(format!("{:#}", check(12).unwrap_err()).contains("12 too large"));
        assert!(format!("{:#}", check(3).unwrap_err()).contains("v != 3"));
    }
}
