#!/usr/bin/env python3
"""Unified static verification driver.

Runs the staticcheck lint battery (see `scripts/staticcheck/`) over the
repository, then the bench-schema validator — one entry point for CI
and authoring containers alike:

    python3 scripts/check.py            # whole repo, all lints + schema
    python3 scripts/check.py --root X   # point at another tree (tests)
    python3 scripts/check.py --no-bench-schema
    python3 scripts/check.py --sarif out.sarif   # SARIF 2.1.0 log for CI
    python3 scripts/check.py --list-waived       # waived findings + waiver
                                                 # live/stale audit

Exits non-zero if any lint produced an unwaived finding or the bench
schema is invalid. Waived findings are listed (with their reasons) but
do not fail the run. This pass complements tier-1 (`cargo build &&
cargo test`) — it never replaces it.
"""

import argparse
import subprocess
import sys
from pathlib import Path

SCRIPTS_DIR = Path(__file__).resolve().parent
sys.path.insert(0, str(SCRIPTS_DIR))

from staticcheck import RepoContext  # noqa: E402
from staticcheck.lints import ALL_LINTS  # noqa: E402


def run_lints(root, out=sys.stdout):
    """Run every lint against `root`; returns (errors, waived, repo).

    The RepoContext is returned so callers can read `repo.waiver_log`
    (the per-waiver live/stale audit filled in by the lints).
    """
    repo = RepoContext(root)
    errors, waived = [], []
    for lint in ALL_LINTS:
        findings = lint.run(repo)
        lint_errors = [f for f in findings if not f.waived]
        lint_waived = [f for f in findings if f.waived]
        status = "ok" if not lint_errors else f"{len(lint_errors)} error(s)"
        extra = f", {len(lint_waived)} waived" if lint_waived else ""
        print(f"[{lint.NAME}] {status}{extra}", file=out)
        for f in lint_errors:
            print(f.format(), file=out)
        errors.extend(lint_errors)
        waived.extend(lint_waived)
    return errors, waived, repo


def run_bench_schema(root, out=sys.stdout):
    """Invoke the bench-schema validator; returns True on success."""
    validator = Path(root) / "scripts" / "validate_bench_schema.py"
    bench = Path(root) / "BENCH_hotpath.json"
    if not validator.is_file() or not bench.is_file():
        print("[bench-schema] skipped (validator or BENCH file absent)", file=out)
        return True
    proc = subprocess.run(
        [sys.executable, str(validator), str(bench)],
        capture_output=True, text=True,
    )
    tag = "ok" if proc.returncode == 0 else "FAILED"
    print(f"[bench-schema] {tag}", file=out)
    for stream in (proc.stdout, proc.stderr):
        if stream.strip():
            for line in stream.strip().splitlines():
                print(f"  {line}", file=out)
    return proc.returncode == 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--root", default=str(SCRIPTS_DIR.parent),
        help="repository root to check (default: this repo)",
    )
    ap.add_argument(
        "--no-bench-schema", action="store_true",
        help="skip the BENCH_hotpath.json schema validation step",
    )
    ap.add_argument(
        "--list-waived", action="store_true",
        help="also print every waived finding with its reason, plus a"
             " live/stale line per waiver comment",
    )
    ap.add_argument(
        "--sarif", metavar="PATH",
        help="write all findings (waived ones as suppressed results) as a"
             " SARIF 2.1.0 log to PATH",
    )
    args = ap.parse_args(argv)

    errors, waived, repo = run_lints(args.root)
    if args.list_waived:
        print(f"-- {len(waived)} waived finding(s):")
        for f in waived:
            print(f.format())
        print(f"-- {len(repo.waiver_log)} waiver comment(s):")
        for (rel, line), w in sorted(repo.waiver_log.items()):
            state = "live" if w["live"] else "STALE"
            print(f"  {rel}:{line}: allow({w['category']}, "
                  f"\"{w['reason']}\") — {state}")

    if args.sarif:
        from staticcheck.sarif import write_sarif

        write_sarif(args.sarif, errors + waived, ALL_LINTS)
        print(f"-- SARIF log written to {args.sarif}")

    schema_ok = True
    if not args.no_bench_schema:
        schema_ok = run_bench_schema(args.root)

    n_waived = len(waived)
    if errors or not schema_ok:
        print(f"check: FAILED — {len(errors)} unwaived finding(s)"
              + ("" if schema_ok else ", bench schema invalid"))
        return 1
    print(f"check: ok ({n_waived} waived finding(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
