"""staticcheck — toolchain-free static verification for this repo.

A stdlib-only static analysis pass over the Rust sources (and the
layers they must agree with: Cargo.toml, configs/*.toml, README.md).
It exists because the authoring containers for this repo historically
lacked cargo/rustc: the lints here catch the compiler-shaped and
repo-contract-shaped bug classes (dangling module paths, undeclared
features, panics on the degraded-serving path, doc drift) *before*
tier-1 ever runs. It complements — never replaces — `cargo build &&
cargo test`.

Entry point: `scripts/check.py` (or `python3 -m` on this package's
driver functions). Lints live in `staticcheck.lints`; each exposes
`run(repo) -> list[Finding]`.
"""

from .report import Finding, Waiver  # noqa: F401
from .repo import RepoContext  # noqa: F401

__version__ = "1.0"
