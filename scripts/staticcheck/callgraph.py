"""Whole-crate call graph over the tokenized item index.

Extracts every function/method *definition* (with its `impl` self type,
trait, `#[cfg(test)]` status and `#[test]` marker) and every call *site*
(direct `foo(…)`, path `Type::method(…)` / `module::foo(…)` incl.
turbofish and `<Type as Trait>::method(…)` UFCS, and method `recv.m(…)`
with best-effort receiver resolution), then links sites to definitions:

- `self.m(…)` / `Self::helper(…)` resolve against the enclosing `impl`
  block's self type.
- A receiver that is a typed `fn` parameter or a `let` binding with an
  explicit annotation (or a `Type::ctor(…)` right-hand side) resolves to
  that type's methods; wrapper types (`&`, `&mut`, `Arc`, `Rc`, `Box`,
  `Cow`) are stripped down to the inner type first.
- A call through a *trait* method (qualifier is a trait name, or the
  receiver's resolved type has no own method of that name) fans out
  conservatively to every in-crate impl of the method — e.g.
  `Prober::extend` edges to all index probers — plus the trait's default
  body, if any.
- An unresolvable receiver (chained calls, field access, untyped
  locals) fans out to *every* in-crate method of that name. This
  over-approximates reachability, never under-approximates it: the
  panic-reach lint stays sound, at the price of false edges that the
  waiver file documents.

Known false-negative classes (documented in README §"Static
verification"): function pointers / closures passed as values, macro
bodies that call crate functions, trait objects dispatched through
external-crate traits, and `Deref`-chained calls to types the wrapper
list above does not name.

Like `items.py`, this is a recognizer for the Rust subset the repo
uses, not a language parser.
"""

import re
from dataclasses import dataclass, field

from .items import make_cfg, _match_bracket
from .tokenizer import code_tokens, match_brace, KEYWORDS

# Wrapper types stripped when resolving a receiver's base type:
# `&Arc<RangeLshIndex<C>>` resolves to `RangeLshIndex`.
WRAPPERS = frozenset(["Arc", "Rc", "Box", "Cow", "RefCell", "Cell"])

PANIC_METHODS = frozenset(["unwrap", "expect", "unwrap_err", "expect_err"])
PANIC_MACROS = frozenset(["panic", "unreachable", "todo", "unimplemented"])


@dataclass
class PanicSite:
    line: int
    what: str  # e.g. ".unwrap()", "panic!", "index/slice"


@dataclass
class CallSite:
    name: str  # called function/method name
    line: int
    kind: str  # "method" | "path" | "bare"
    recv: str = ""  # resolved receiver/qualifier type or trait name; "" unknown


@dataclass
class FnNode:
    name: str
    file: str  # repo-relative path
    line: int
    crate: str  # root file of the crate this definition belongs to
    self_type: str = ""  # impl self type ("" for free fns / trait decls)
    trait_name: str = ""  # trait implemented / defaulted on ("" otherwise)
    test_only: bool = False  # under cfg(test) (or in a test-only module)
    is_test: bool = False  # carries #[test]
    calls: list = field(default_factory=list)  # [CallSite]
    panics: list = field(default_factory=list)  # [PanicSite]
    id: int = -1

    @property
    def qname(self):
        owner = self.self_type or self.trait_name
        return f"{owner}::{self.name}" if owner else self.name


@dataclass
class CallGraph:
    nodes: list = field(default_factory=list)
    # name -> [node ids]; methods_by_name only lists fns with an owner
    by_name: dict = field(default_factory=dict)
    methods_by_name: dict = field(default_factory=dict)
    free_by_name: dict = field(default_factory=dict)
    # self type -> {method name -> [ids]}
    by_type: dict = field(default_factory=dict)
    # trait name -> {method name -> [ids]} (impls + default bodies)
    trait_impls: dict = field(default_factory=dict)
    trait_names: set = field(default_factory=set)
    type_names: set = field(default_factory=set)
    # (caller id -> [(callee id, call line)]), built lazily
    _edges: dict = field(default_factory=dict)

    def add(self, node):
        node.id = len(self.nodes)
        self.nodes.append(node)
        self.by_name.setdefault(node.name, []).append(node.id)
        if node.self_type or node.trait_name:
            self.methods_by_name.setdefault(node.name, []).append(node.id)
        else:
            self.free_by_name.setdefault(node.name, []).append(node.id)
        if node.self_type:
            self.by_type.setdefault(node.self_type, {}).setdefault(
                node.name, []
            ).append(node.id)
            self.type_names.add(node.self_type)
        if node.trait_name:
            self.trait_impls.setdefault(node.trait_name, {}).setdefault(
                node.name, []
            ).append(node.id)
            self.trait_names.add(node.trait_name)
        return node

    # -- call resolution ----------------------------------------------

    def resolve_call(self, site, caller):
        """Node ids a call site may dispatch to (conservative)."""
        name = site.name
        if site.kind == "method":
            return self._resolve_method(name, site.recv)
        if site.kind == "path":
            q = site.recv
            if q in ("Self",):
                q = caller.self_type
            if q == "":
                return list(self.free_by_name.get(name, ()))
            if q in self.trait_names:
                return self._trait_fanout(q, name)
            if q in self.by_type:
                own = self.by_type[q].get(name)
                if own:
                    return list(own)
                # inherent name not found on the type: maybe a trait
                # method called through the type — fan out.
                return self._resolve_method(name, "")
            # lowercase qualifier: a module path — free functions
            if q[:1].islower():
                return list(self.free_by_name.get(name, ()))
            # Unknown type qualifier (external / generic): enum variant
            # constructors land here too — only match if the crate
            # defines methods of that name somewhere.
            return []
        # bare call: free functions only (locals/closures resolve to
        # nothing, which is correct — we cannot see through fn values).
        return list(self.free_by_name.get(name, ()))

    def _resolve_method(self, name, recv_type):
        if recv_type:
            own = self.by_type.get(recv_type, {}).get(name)
            if own:
                return list(own)
            if recv_type in self.trait_names:
                return self._trait_fanout(recv_type, name)
        # Unresolved (or resolved to a type without that inherent
        # method, e.g. a generic param bound by a trait): every in-crate
        # method of that name, trait defaults included.
        return list(self.methods_by_name.get(name, ()))

    def _trait_fanout(self, trait, name):
        return list(self.trait_impls.get(trait, {}).get(name, ()))

    # -- graph queries ------------------------------------------------

    def edges(self):
        """caller id -> [(callee id, call line)], resolved once."""
        if not self._edges:
            for node in self.nodes:
                out = []
                for site in node.calls:
                    for callee in self.resolve_call(site, node):
                        out.append((callee, site.line))
                self._edges[node.id] = out
        return self._edges

    def edge_count(self):
        return sum(len(set(c for c, _ in v)) for v in self.edges().values())

    def reachable_from(self, start_ids, node_filter=None):
        """BFS; returns {reached id: (parent id or None, call line)}.

        `node_filter(node) -> bool` prunes traversal (e.g. keep the walk
        inside the library crate). Parent pointers give shortest witness
        paths because the walk is breadth-first.
        """
        edges = self.edges()
        parent = {}
        frontier = []
        for s in start_ids:
            if s not in parent:
                parent[s] = (None, 0)
                frontier.append(s)
        while frontier:
            nxt = []
            for u in frontier:
                for v, line in edges.get(u, ()):
                    if v in parent:
                        continue
                    if node_filter is not None and not node_filter(self.nodes[v]):
                        continue
                    parent[v] = (u, line)
                    nxt.append(v)
            frontier = nxt
        return parent

    def witness_path(self, parent, node_id):
        """[(FnNode, call line)] from an entry point down to `node_id`."""
        path = []
        cur = node_id
        while cur is not None:
            p, line = parent[cur]
            path.append((self.nodes[cur], line))
            cur = p
        path.reverse()
        return path

    def format_path(self, parent, node_id):
        parts = []
        for node, line in self.witness_path(parent, node_id):
            loc = f" ({node.file}:{line})" if line else ""
            parts.append(f"{node.qname}{loc}")
        return " -> ".join(parts)


# ---------------------------------------------------------------------------
# Extraction


class _Scanner:
    def __init__(self, graph, crate_root):
        self.graph = graph
        self.crate = crate_root

    def scan_file(self, rel, toks, test_only):
        self._scope(toks, 0, len(toks), rel, "", "", test_only, None, None)

    # ctx: (impl self type, trait name, test_only); owner: enclosing FnNode
    def _scope(self, toks, lo, hi, rel, self_ty, trait, test_only, owner, env):
        i = lo
        attrs = []
        while i < hi:
            t = toks[i]
            if t.kind == "punct" and t.value == "#":
                j = i + 1
                if j < hi and toks[j].kind == "punct" and toks[j].value == "!":
                    j += 1
                if j < hi and toks[j].kind == "punct" and toks[j].value == "[":
                    end = _match_bracket(toks, j, hi)
                    attrs.append(" ".join(tk.value for tk in toks[i : end + 1]))
                    i = end + 1
                    continue
                i += 1
                continue
            if t.kind != "ident":
                if owner is not None:
                    self._expr_token(toks, i, hi, rel, owner)
                attrs = []
                i += 1
                continue

            kw = t.value
            # visibility / unsafe prefixes
            if kw == "pub":
                i += 1
                if i < hi and toks[i].kind == "punct" and toks[i].value == "(":
                    i = _match_paren(toks, i, hi) + 1
                continue
            if kw == "unsafe" and i + 1 < hi and toks[i + 1].kind == "ident" and (
                toks[i + 1].value in ("fn", "impl", "trait")
            ):
                i += 1
                continue
            if kw == "mod" and i + 1 < hi and toks[i + 1].kind == "ident":
                cfg = make_cfg(attrs)
                attrs = []
                j = i + 2
                if j < hi and toks[j].kind == "punct" and toks[j].value == "{":
                    end = match_brace(toks, j)
                    self._scope(
                        toks, j + 1, end, rel, "", "",
                        test_only or cfg.test_only, None, None,
                    )
                    i = end + 1
                else:
                    i = j + 1  # `mod foo;` — the file scanner covers it
                continue
            if kw == "impl" and owner is None:
                cfg = make_cfg(attrs)
                attrs = []
                i = self._impl(toks, i, hi, rel, test_only or cfg.test_only)
                continue
            if kw == "trait" and i + 1 < hi and toks[i + 1].kind == "ident":
                cfg = make_cfg(attrs)
                attrs = []
                name = toks[i + 1].value
                j = _skip_to_brace(toks, i + 2, hi)
                if j < hi:
                    end = match_brace(toks, j)
                    self._scope(
                        toks, j + 1, end, rel, "", name,
                        test_only or cfg.test_only, None, None,
                    )
                    i = end + 1
                else:
                    i = j
                continue
            if kw == "fn" and i + 1 < hi and toks[i + 1].kind == "ident":
                cfg = make_cfg(attrs)
                is_test = any(_is_test_attr(a) for a in attrs)
                attrs = []
                i = self._fn(
                    toks, i, hi, rel, self_ty, trait,
                    test_only or cfg.test_only, is_test,
                )
                continue
            if kw == "let" and owner is not None and env is not None:
                i = self._let(toks, i, hi, env)
                continue

            if owner is not None:
                self._ident_in_expr(toks, i, hi, rel, owner, env, self_ty)
            attrs = []
            i += 1

    # -- items --------------------------------------------------------

    def _impl(self, toks, i, hi, rel, test_only):
        """Parse `impl<…> [Trait<…> for] Type<…> [where …] { … }`."""
        j = i + 1
        if j < hi and toks[j].kind == "punct" and toks[j].value == "<":
            j = _match_angle(toks, j, hi) + 1
        first, j = _type_path(toks, j, hi)
        trait, self_ty = "", first
        if j < hi and toks[j].kind == "ident" and toks[j].value == "for":
            second, j = _type_path(toks, j + 1, hi)
            trait, self_ty = first, second
        j = _skip_to_brace(toks, j, hi)
        if j >= hi:
            return j
        end = match_brace(toks, j)
        self._scope(toks, j + 1, end, rel, self_ty, trait, test_only, None, None)
        return end + 1

    def _fn(self, toks, i, hi, rel, self_ty, trait, test_only, is_test):
        name_tok = toks[i + 1]
        node = self.graph.add(
            FnNode(
                name=name_tok.value, file=rel, line=name_tok.line,
                crate=self.crate, self_type=self_ty, trait_name=trait,
                test_only=test_only, is_test=is_test,
            )
        )
        j = i + 2
        if j < hi and toks[j].kind == "punct" and toks[j].value == "<":
            j = _match_angle(toks, j, hi) + 1
        env = {}
        if j < hi and toks[j].kind == "punct" and toks[j].value == "(":
            close = _match_paren(toks, j, hi)
            _param_env(toks, j + 1, close, env, self_ty)
            j = close + 1
        # skip the return type / where clause to the body `{` or `;`
        depth_p = depth_b = 0
        while j < hi:
            t = toks[j]
            v = t.value if t.kind == "punct" else ""
            if v == "(":
                depth_p += 1
            elif v == ")":
                depth_p -= 1
            elif v == "[":
                depth_b += 1
            elif v == "]":
                depth_b -= 1
            elif v == "{" and depth_p == 0 and depth_b == 0:
                end = match_brace(toks, j)
                self._scope(
                    toks, j + 1, end, rel, self_ty, trait, test_only, node, env
                )
                return end + 1
            elif v == ";" and depth_p == 0 and depth_b == 0:
                return j + 1  # declaration without body (trait method)
            j += 1
        return hi

    def _let(self, toks, i, hi, env):
        """`let [mut] name [: Type] = …` — record the binding's type."""
        j = i + 1
        if j < hi and toks[j].kind == "ident" and toks[j].value == "mut":
            j += 1
        if j >= hi or toks[j].kind != "ident":
            return i + 1  # destructuring pattern — ignore
        name = toks[j].value
        j += 1
        if j < hi and toks[j].kind == "punct" and toks[j].value == ":":
            ty, j = _base_type(toks, j + 1, hi, stop=("=", ";"))
            if ty:
                env[name] = ty
            return i + 1
        if (
            j + 2 < hi
            and toks[j].kind == "punct" and toks[j].value == "="
            and toks[j + 1].kind == "ident"
            and toks[j + 1].value[:1].isupper()
            and toks[j + 2].kind == "punct" and toks[j + 2].value == ":"
        ):
            # `let x = Type::ctor(…)…` — the common constructor idiom.
            env[name] = toks[j + 1].value
        return i + 1

    # -- expression-level scanning ------------------------------------

    def _ident_in_expr(self, toks, i, hi, rel, owner, env, self_ty):
        t = toks[i]
        nxt = toks[i + 1] if i + 1 < hi else None
        prv = toks[i - 1] if i > 0 else None

        # macro invocation: `name ! (…)` / `name ! [...]` / `name ! {…}`
        if nxt is not None and nxt.kind == "punct" and nxt.value == "!":
            if t.value in PANIC_MACROS:
                owner.panics.append(PanicSite(t.line, f"{t.value}!"))
            return

        is_call_head = nxt is not None and nxt.kind == "punct" and nxt.value == "("
        # turbofish: `name ::< … > (`
        if (
            not is_call_head
            and nxt is not None and nxt.kind == "punct" and nxt.value == ":"
            and i + 3 < hi
            and toks[i + 2].kind == "punct" and toks[i + 2].value == ":"
            and toks[i + 3].kind == "punct" and toks[i + 3].value == "<"
        ):
            close = _match_angle(toks, i + 3, hi)
            if close + 1 < hi and toks[close + 1].kind == "punct" and toks[close + 1].value == "(":
                is_call_head = True
        if not is_call_head:
            return

        name = t.value
        if name in KEYWORDS:
            return
        if prv is not None and prv.kind == "punct" and prv.value == ".":
            if name in PANIC_METHODS:
                owner.panics.append(PanicSite(t.line, f".{name}()"))
                return
            recv = self._receiver(toks, i - 2, env, self_ty)
            owner.calls.append(CallSite(name, t.line, "method", recv))
            return
        if (
            prv is not None and prv.kind == "punct" and prv.value == ":"
            and i >= 2 and toks[i - 2].kind == "punct" and toks[i - 2].value == ":"
        ):
            qual = self._path_qualifier(toks, i - 2, env, self_ty)
            owner.calls.append(CallSite(name, t.line, "path", qual))
            return
        if prv is not None and prv.kind == "ident" and prv.value == "fn":
            return  # definition, handled structurally
        owner.calls.append(CallSite(name, t.line, "bare", ""))

    def _receiver(self, toks, ri, env, self_ty):
        """Type of the receiver ending at token index `ri`, or ""."""
        if ri < 0:
            return ""
        r = toks[ri]
        if r.kind != "ident":
            return ""  # chained call `f(x).m()`, index `xs[i].m()`, …
        before = toks[ri - 1] if ri > 0 else None
        if before is not None and before.kind == "punct" and before.value in ".:":
            return ""  # field access / path — unresolved
        if r.value == "self":
            return self_ty
        return env.get(r.value, "") if env is not None else ""

    def _path_qualifier(self, toks, colon_i, env, self_ty):
        """Qualifier of `Qual::name(` whose `::` ends at `colon_i`."""
        j = colon_i - 1
        if j >= 0 and toks[j].kind == "punct" and toks[j].value == ">":
            # turbofish `Type::<T>::m(` or UFCS `<Type as Trait>::m(`
            open_i = _match_angle_back(toks, j)
            k = open_i - 1
            if (
                k >= 2
                and toks[k].kind == "punct" and toks[k].value == ":"
                and toks[k - 1].kind == "punct" and toks[k - 1].value == ":"
                and toks[k - 2].kind == "ident"
            ):
                j = k - 2  # `Type ::< T > :: m(` — qualifier before `::<`
            elif k >= 0 and toks[k].kind == "ident":
                j = k  # `Type< T > :: m(` in type position
            else:
                # UFCS: first ident inside `<…>` is the concrete type
                for k2 in range(open_i + 1, j):
                    if toks[k2].kind == "ident":
                        ty = toks[k2].value
                        return self_ty if ty == "Self" else ty
                return ""
        if j < 0 or toks[j].kind != "ident":
            return ""
        ty = toks[j].value
        if ty == "Self":
            return self_ty
        return ty

    def _expr_token(self, toks, i, hi, rel, owner):
        """Non-ident token inside a body: bare index/slice detection."""
        t = toks[i]
        if t.kind != "punct" or t.value != "[" or i == 0:
            return
        prv = toks[i - 1]
        is_index = (
            (prv.kind == "ident" and prv.value not in KEYWORDS)
            or (prv.kind == "punct" and prv.value in ")]")
            or prv.kind == "num"
        )
        if is_index:
            owner.panics.append(PanicSite(t.line, "index/slice"))


def _is_test_attr(attr):
    return re.fullmatch(r"#\s*\[\s*test\s*\]", attr) is not None


def _match_paren(toks, open_idx, hi):
    depth = 0
    for k in range(open_idx, hi):
        v = toks[k].value if toks[k].kind == "punct" else ""
        if v == "(":
            depth += 1
        elif v == ")":
            depth -= 1
            if depth == 0:
                return k
    return hi - 1


def _match_angle(toks, open_idx, hi):
    """Match `<…>` skipping `->` arrows; returns index of closing `>`."""
    depth = 0
    k = open_idx
    while k < hi:
        t = toks[k]
        v = t.value if t.kind == "punct" else ""
        if v == "<":
            depth += 1
        elif v == ">":
            if k > 0 and toks[k - 1].kind == "punct" and toks[k - 1].value == "-":
                k += 1
                continue
            depth -= 1
            if depth == 0:
                return k
        k += 1
    return hi - 1


def _match_angle_back(toks, close_idx):
    """Index of the `<` matching the `>` at `close_idx` (backwards)."""
    depth = 0
    for k in range(close_idx, -1, -1):
        v = toks[k].value if toks[k].kind == "punct" else ""
        if v == ">":
            depth += 1
        elif v == "<":
            depth -= 1
            if depth == 0:
                return k
    return 0


def _skip_to_brace(toks, i, hi):
    depth_p = depth_b = 0
    while i < hi:
        t = toks[i]
        v = t.value if t.kind == "punct" else ""
        if v == "(":
            depth_p += 1
        elif v == ")":
            depth_p -= 1
        elif v == "[":
            depth_b += 1
        elif v == "]":
            depth_b -= 1
        elif v == "{" and depth_p == 0 and depth_b == 0:
            return i
        elif v == ";" and depth_p == 0 and depth_b == 0:
            return hi
        i += 1
    return hi


def _type_path(toks, i, hi):
    """Read `seg::seg<…>` at `i`; returns (last segment name, next index)."""
    last = ""
    while i < hi:
        t = toks[i]
        if t.kind == "ident":
            if t.value in ("for", "where"):
                break
            last = t.value
            i += 1
            if i < hi and toks[i].kind == "punct" and toks[i].value == "<":
                i = _match_angle(toks, i, hi) + 1
            if (
                i + 1 < hi
                and toks[i].kind == "punct" and toks[i].value == ":"
                and toks[i + 1].kind == "punct" and toks[i + 1].value == ":"
            ):
                i += 2
                continue
            break
        if t.kind == "punct" and t.value in "&'":
            i += 1
            continue
        if t.kind == "lifetime":
            i += 1
            continue
        break
    return last, i


def _param_env(toks, lo, hi, env, self_ty):
    """Bind `name: Type` fn parameters into `env`."""
    # split on top-level commas
    start, depth = lo, 0
    spans = []
    for k in range(lo, hi):
        t = toks[k]
        v = t.value if t.kind == "punct" else ""
        if v in "([<":
            # `<` here is generic args inside a type — arrows are rare
            # in param lists; treat all three as nesting.
            depth += 1
        elif v in ")]>":
            depth -= 1
        elif v == "," and depth == 0:
            spans.append((start, k))
            start = k + 1
    if start < hi:
        spans.append((start, hi))
    for lo2, hi2 in spans:
        # find top-level `:`
        depth = 0
        colon = -1
        for k in range(lo2, hi2):
            t = toks[k]
            v = t.value if t.kind == "punct" else ""
            if v in "([<":
                depth += 1
            elif v in ")]>":
                depth -= 1
            elif v == ":" and depth == 0:
                # `::` is two tokens; skip path separators
                if k + 1 < hi2 and toks[k + 1].kind == "punct" and toks[k + 1].value == ":":
                    continue
                if k > lo2 and toks[k - 1].kind == "punct" and toks[k - 1].value == ":":
                    continue
                colon = k
                break
        if colon < 0:
            continue
        # pattern: accept `name` / `mut name` / `ref name`
        pat = [t for t in toks[lo2:colon] if t.kind == "ident"]
        if not pat:
            continue
        name = pat[-1].value
        if name in ("self", "mut", "ref") or any(
            t.kind == "punct" and t.value in "({" for t in toks[lo2:colon]
        ):
            continue
        ty, _ = _base_type(toks, colon + 1, hi2, stop=(",",))
        if ty:
            env[name] = ty
    if self_ty:
        env.setdefault("self", self_ty)


def _base_type(toks, i, hi, stop=()):
    """Base type name of the type starting at `i`, wrappers stripped.

    `&mut Arc<RangeLshIndex<C>>` -> "RangeLshIndex". Returns ("",
    index) when the type is not a plain path (slices, tuples, fn
    pointers, …).
    """
    # strip leading `&`, lifetimes, `mut`, `dyn`, `impl`
    while i < hi:
        t = toks[i]
        if t.kind == "punct" and t.value == "&":
            i += 1
        elif t.kind == "lifetime":
            i += 1
        elif t.kind == "ident" and t.value in ("mut", "dyn", "impl"):
            i += 1
        else:
            break
    last = ""
    while i < hi:
        t = toks[i]
        v = t.value if t.kind == "punct" else ""
        if t.kind == "ident":
            if v and v in stop:
                break
            last = t.value
            i += 1
            if i < hi and toks[i].kind == "punct" and toks[i].value == "<":
                close = _match_angle(toks, i, hi)
                if last in WRAPPERS:
                    inner, _ = _base_type(toks, i + 1, close, stop=(",",))
                    if inner:
                        last = inner
                i = close + 1
            if (
                i + 1 < hi
                and toks[i].kind == "punct" and toks[i].value == ":"
                and toks[i + 1].kind == "punct" and toks[i + 1].value == ":"
            ):
                i += 2
                continue
            break
        if v in stop or v in ";)":
            break
        # non-path types (slices `[T]`, tuples, fn pointers) — give up
        return "", i
    return last, i


# ---------------------------------------------------------------------------
# Crate walking


def crate_files(index):
    """(repo-relative file, test_only) pairs for every module file of an
    item index, de-duplicated (a file hosting inline submodules appears
    once, with its outermost module's test status)."""
    seen = {}
    for mod in index.all_modules():
        if mod.file not in seen or (seen[mod.file] and not mod.test_only):
            seen[mod.file] = mod.test_only
    return sorted(seen.items())


def build_graph(repo, crate_roots):
    """One merged CallGraph over the given crate roots.

    `crate_roots` are root files (e.g. `rust/src/lib.rs`,
    `tests/properties.rs`); every module file each root pulls in is
    scanned. Files shared between crates (rare) are scanned once per
    crate, so nodes carry their crate of origin.
    """
    graph = CallGraph()
    for root in crate_roots:
        index = repo.index_for(root)
        if index is None:
            continue
        scanner = _Scanner(graph, root)
        for rel, test_only in crate_files(index):
            toks = repo.tokens(rel)
            if toks is None:
                continue
            scanner.scan_file(rel, code_tokens(toks), test_only)
    return graph
