"""Module tree + item index over the Rust sources.

Builds, from a crate root (`rust/src/lib.rs`, or any of the bin / test /
bench / example roots), the tree of modules with every item each one
declares: structs, enums (with variants), traits, fns, consts, statics,
type aliases, `macro_rules!` macros, unions, re-exports (`pub use`) and
plain `use` declarations. Each carries its `#[cfg(…)]` condition so the
feature-gate lint can reason about test-only items.

This is a *recognizer* for the Rust subset the repo uses, not a parser
for the language: item boundaries are found by keyword + balanced
delimiter scanning over the token stream from `tokenizer`.
"""

import re
from dataclasses import dataclass, field
from pathlib import Path

from .tokenizer import tokenize, code_tokens, match_brace

FEATURE_RE = re.compile(r"feature\s*=\s*\"([^\"]+)\"")

# Item-introducing keywords handled by the body scanner.
_SEMI_ITEMS = frozenset(["const", "static", "type"])
_BRACE_ITEMS = frozenset(["trait", "union"])


@dataclass
class Cfg:
    """A `#[cfg(…)]` condition attached to an item."""

    raw: str = ""  # the condition text, e.g. 'any(test, feature = "x")'
    test_only: bool = False  # item vanishes from non-test builds
    features: tuple = ()  # every feature name the condition mentions

    @staticmethod
    def none():
        return Cfg()


@dataclass
class Item:
    name: str
    kind: str  # fn | struct | enum | trait | const | static | type | macro | union | extern-crate
    line: int
    cfg: Cfg = field(default_factory=Cfg.none)
    variants: tuple = ()  # enum variants


@dataclass
class ReExport:
    name: str  # exposed name (alias or last target segment); "" for glob
    target: tuple  # target path segments as written
    glob: bool
    line: int
    cfg: Cfg = field(default_factory=Cfg.none)


@dataclass
class UseDecl:
    segments: tuple
    line: int
    path: str  # repo-relative file it appears in
    in_test: bool  # inside a cfg(test)-only scope
    glob: bool = False


@dataclass
class Module:
    name: str
    file: str  # repo-relative path of the file declaring its body
    test_only: bool = False
    items: dict = field(default_factory=dict)
    submodules: dict = field(default_factory=dict)
    reexports: list = field(default_factory=list)
    uses: list = field(default_factory=list)


@dataclass
class CrateIndex:
    root: Module
    crate_name: str
    problems: list = field(default_factory=list)  # (path, line, message)
    cfg_features: list = field(default_factory=list)  # (path, line, feature)

    def all_modules(self):
        stack = [self.root]
        while stack:
            m = stack.pop()
            yield m
            stack.extend(m.submodules.values())

    def all_uses(self):
        for m in self.all_modules():
            yield from m.uses


def _parse_cfg_condition(cond):
    """Evaluate a cfg condition with test=False, everything else True.

    An item is test-only exactly when its condition is False under that
    assignment: cfg(test) and cfg(all(test, …)) vanish from non-test
    builds, cfg(any(test, feature = "x")) does not.
    """
    toks = re.findall(r'[A-Za-z_][A-Za-z0-9_]*|"[^"]*"|[(),=]', cond)
    pos = [0]

    def parse():
        if pos[0] >= len(toks):
            return True
        t = toks[pos[0]]
        pos[0] += 1
        if t in ("any", "all", "not") and pos[0] < len(toks) and toks[pos[0]] == "(":
            pos[0] += 1  # '('
            args = []
            while pos[0] < len(toks) and toks[pos[0]] != ")":
                if toks[pos[0]] == ",":
                    pos[0] += 1
                    continue
                args.append(parse())
            pos[0] += 1  # ')'
            if t == "any":
                return any(args)
            if t == "all":
                return all(args)
            return not args[0] if args else True
        # key = "value" pairs: consume them
        if pos[0] + 1 < len(toks) and toks[pos[0]] == "=":
            pos[0] += 2
            return True  # feature/target_os/… assumed enabled
        return t != "test"

    return parse()


def make_cfg(attr_texts):
    """Combine the cfg conditions of an item's attributes."""
    conds, features = [], []
    for a in attr_texts:
        m = re.search(r"\bcfg\s*\((.*)\)\s*\]\s*$", a, re.S)
        if m:
            conds.append(m.group(1).strip())
        features.extend(FEATURE_RE.findall(a))
    test_only = any(not _parse_cfg_condition(c) for c in conds)
    return Cfg(raw="; ".join(conds), test_only=test_only, features=tuple(features))


class _Parser:
    def __init__(self, index, repo_root):
        self.index = index
        self.repo_root = Path(repo_root)

    def parse_file(self, module, file_path, child_dir, in_test):
        rel = str(Path(file_path).relative_to(self.repo_root))
        try:
            text = Path(file_path).read_text()
        except OSError as e:
            self.index.problems.append((rel, 0, f"unreadable module file: {e}"))
            return
        toks = code_tokens(tokenize(text))
        self.parse_body(module, toks, 0, len(toks), rel, child_dir, in_test)

    def parse_body(self, module, toks, lo, hi, rel, child_dir, in_test):
        i = lo
        pending_attrs = []
        while i < hi:
            t = toks[i]
            if t.kind == "punct" and t.value == "#":
                # attribute: # [ … ]  (or inner #![…])
                j = i + 1
                if j < hi and toks[j].kind == "punct" and toks[j].value == "!":
                    j += 1
                if j < hi and toks[j].kind == "punct" and toks[j].value == "[":
                    end = _match_bracket(toks, j, hi)
                    attr = " ".join(tk.value for tk in toks[i : end + 1])
                    pending_attrs.append((attr, t.line))
                    for feat in FEATURE_RE.findall(attr):
                        self.index.cfg_features.append((rel, t.line, feat))
                    i = end + 1
                    continue
                i += 1
                continue
            if t.kind != "ident":
                i += 1
                pending_attrs = []
                continue

            kw = t.value
            cfg = make_cfg([a for a, _ in pending_attrs])
            if in_test and not cfg.test_only:
                # items inside a cfg(test) module are test-only too
                cfg = Cfg(cfg.raw, True, cfg.features)
            pending_attrs = []

            # visibility prefix
            if kw == "pub":
                i += 1
                if i < hi and toks[i].kind == "punct" and toks[i].value == "(":
                    i = _match_paren(toks, i, hi) + 1
                if i >= hi or toks[i].kind != "ident":
                    continue
                kw = toks[i].value
                t = toks[i]
                is_pub = True
            else:
                is_pub = False
            if kw == "unsafe" and i + 1 < hi and toks[i + 1].kind == "ident":
                i += 1
                kw = toks[i].value
                t = toks[i]

            if kw == "use":
                trees, i = _parse_use(toks, i + 1, hi)
                for segs, glob, alias in trees:
                    module.uses.append(
                        UseDecl(tuple(segs), t.line, rel, in_test or cfg.test_only, glob)
                    )
                    if is_pub:
                        name = alias or (segs[-1] if segs else "")
                        module.reexports.append(
                            ReExport(name if not glob else "", tuple(segs), glob, t.line, cfg)
                        )
                continue
            if kw == "mod":
                i = self._parse_mod(module, toks, i, hi, rel, child_dir, in_test, cfg)
                continue
            if kw == "fn":
                name, i = _ident_after(toks, i + 1, hi)
                if name:
                    module.items.setdefault(name, Item(name, "fn", t.line, cfg))
                i = _skip_to_body_or_semi(toks, i, hi)
                continue
            if kw == "struct":
                name, i = _ident_after(toks, i + 1, hi)
                if name:
                    module.items[name] = Item(name, "struct", t.line, cfg)
                i = _skip_to_body_or_semi(toks, i, hi)
                continue
            if kw == "enum":
                name, i = _ident_after(toks, i + 1, hi)
                body_end = _skip_to_body_or_semi(toks, i, hi)
                variants = _enum_variants(toks, i, body_end)
                if name:
                    module.items[name] = Item(name, "enum", t.line, cfg, tuple(variants))
                i = body_end
                continue
            if kw in _BRACE_ITEMS:
                name, i = _ident_after(toks, i + 1, hi)
                if name:
                    module.items[name] = Item(name, kw, t.line, cfg)
                i = _skip_to_body_or_semi(toks, i, hi)
                continue
            if kw in _SEMI_ITEMS:
                # `const fn` is a fn, `const _: () = …` is unnamed
                if kw == "const" and i + 1 < hi and toks[i + 1].value == "fn":
                    i += 1
                    continue
                name, i = _ident_after(toks, i + 1, hi)
                if name and name != "_":
                    module.items[name] = Item(name, kw, t.line, cfg)
                i = _skip_to_body_or_semi(toks, i, hi)
                continue
            if kw == "macro_rules":
                # macro_rules ! name { … }
                j = i + 1
                if j < hi and toks[j].value == "!":
                    name, j = _ident_after(toks, j + 1, hi)
                    if name:
                        module.items[name] = Item(name, "macro", t.line, cfg)
                i = _skip_to_body_or_semi(toks, j if j > i else i + 1, hi)
                continue
            if kw == "impl":
                i = _skip_to_body_or_semi(toks, i + 1, hi)
                continue
            if kw == "extern":
                if i + 1 < hi and toks[i + 1].value == "crate":
                    name, i = _ident_after(toks, i + 2, hi)
                    if name:
                        module.items[name] = Item(name, "extern-crate", t.line, cfg)
                i = _skip_to_body_or_semi(toks, i, hi)
                continue
            i += 1

    def _parse_mod(self, module, toks, i, hi, rel, child_dir, in_test, cfg):
        line = toks[i].line
        name, i = _ident_after(toks, i + 1, hi)
        if not name:
            return i
        child = Module(name, rel, test_only=in_test or cfg.test_only)
        if i < hi and toks[i].kind == "punct" and toks[i].value == ";":
            # file module: child_dir/name.rs or child_dir/name/mod.rs
            cand = [child_dir / f"{name}.rs", child_dir / name / "mod.rs"]
            found = next((c for c in cand if c.is_file()), None)
            if found is None:
                self.index.problems.append(
                    (rel, line,
                     f"mod {name}; has no backing file ({cand[0].relative_to(self.repo_root)}"
                     f" or {cand[1].relative_to(self.repo_root)})")
                )
            else:
                child.file = str(found.relative_to(self.repo_root))
                self.parse_file(child, found, child_dir / name, child.test_only)
            module.submodules[name] = child
            return i + 1
        if i < hi and toks[i].kind == "punct" and toks[i].value == "{":
            end = match_brace(toks, i)
            self.parse_body(child, toks, i + 1, end, rel, child_dir / name, child.test_only)
            module.submodules[name] = child
            return end + 1
        return i


def _ident_after(toks, i, hi):
    if i < hi and toks[i].kind == "ident":
        return toks[i].value, i + 1
    return None, i


def _match_bracket(toks, open_idx, hi):
    depth = 0
    for k in range(open_idx, hi):
        v = toks[k].value if toks[k].kind == "punct" else ""
        if v == "[":
            depth += 1
        elif v == "]":
            depth -= 1
            if depth == 0:
                return k
    return hi - 1


def _match_paren(toks, open_idx, hi):
    depth = 0
    for k in range(open_idx, hi):
        v = toks[k].value if toks[k].kind == "punct" else ""
        if v == "(":
            depth += 1
        elif v == ")":
            depth -= 1
            if depth == 0:
                return k
    return hi - 1


def _skip_to_body_or_semi(toks, i, hi):
    """Skip past an item tail: its `{…}` body or terminating `;`.

    `;` only terminates at zero (), [] nesting so `[u64; 2]` and tuple
    struct bodies are crossed correctly; a `{` at zero nesting opens the
    item body (matched and skipped). Initializer braces after `=`
    (struct literals in consts) are also just balanced groups here.
    """
    par = brk = 0
    k = i
    while k < hi:
        t = toks[k]
        if t.kind != "punct":
            k += 1
            continue
        v = t.value
        if v == "(":
            par += 1
        elif v == ")":
            par -= 1
        elif v == "[":
            brk += 1
        elif v == "]":
            brk -= 1
        elif v == "{" and par == 0 and brk == 0:
            return match_brace(toks, k) + 1
        elif v == ";" and par == 0 and brk == 0:
            return k + 1
        k += 1
    return hi


def _enum_variants(toks, i, body_end):
    """Variant names of the enum whose tokens end at body_end."""
    # find the opening brace of the enum body
    par = brk = 0
    k = i
    while k < body_end:
        t = toks[k]
        if t.kind == "punct":
            if t.value == "(":
                par += 1
            elif t.value == ")":
                par -= 1
            elif t.value == "[":
                brk += 1
            elif t.value == "]":
                brk -= 1
            elif t.value == "{" and par == 0 and brk == 0:
                break
        k += 1
    if k >= body_end:
        return []
    variants, depth, expect = [], 0, True
    for j in range(k, body_end):
        t = toks[j]
        if t.kind == "punct":
            if t.value in "{([":
                depth += 1
            elif t.value in "})]":
                depth -= 1
            elif t.value == "," and depth == 1:
                expect = True
            elif t.value == "#":
                continue
            continue
        if t.kind == "ident" and depth == 1 and expect:
            variants.append(t.value)
            expect = False
    return variants


def _parse_use(toks, i, hi):
    """Expand the use-tree starting at `i`; returns (trees, index_after).

    Each tree is (segments, is_glob, alias). Stops after the closing `;`.
    """
    trees, i = _parse_use_tree(toks, i, hi, [])
    while i < hi and not (toks[i].kind == "punct" and toks[i].value == ";"):
        i += 1
    return trees, i + 1


def _parse_use_tree(toks, i, hi, prefix):
    segs = list(prefix)
    alias = None
    while i < hi:
        t = toks[i]
        if t.kind == "ident" and t.value == "as":
            if i + 1 < hi and toks[i + 1].kind == "ident":
                alias = toks[i + 1].value
                i += 2
            else:
                i += 1
            break
        if t.kind == "ident":
            segs.append(t.value)
            i += 1
            # `::` ?
            if (
                i + 1 < hi
                and toks[i].kind == "punct" and toks[i].value == ":"
                and toks[i + 1].kind == "punct" and toks[i + 1].value == ":"
            ):
                i += 2
                continue
            break
        if t.kind == "punct" and t.value == "*":
            return [(segs, True, None)], i + 1
        if t.kind == "punct" and t.value == "{":
            out = []
            i += 1
            while i < hi and not (toks[i].kind == "punct" and toks[i].value == "}"):
                if toks[i].kind == "punct" and toks[i].value == ",":
                    i += 1
                    continue
                sub, i = _parse_use_tree(toks, i, hi, segs)
                out.extend(sub)
            return out, i + 1
        break
    return [(segs, False, alias)], i


def build_crate_index(repo_root, root_file, crate_name):
    """Index the crate rooted at `root_file` (repo-relative or absolute)."""
    repo_root = Path(repo_root)
    root_path = repo_root / root_file if not Path(root_file).is_absolute() else Path(root_file)
    root = Module("crate", str(root_path.relative_to(repo_root)))
    index = CrateIndex(root, crate_name)
    _Parser(index, repo_root).parse_file(root, root_path, root_path.parent, False)
    return index


# ---------------------------------------------------------------------------
# Path resolution

RESOLVED, UNRESOLVED, EXTERNAL = "resolved", "unresolved", "external"


def resolve_path(index, segments, lib_index=None):
    """Resolve a use path against a crate index.

    Returns (status, obj) where status is RESOLVED / UNRESOLVED /
    EXTERNAL and obj is the Module or Item reached (RESOLVED only).
    `lib_index` lets bin/test/bench crates resolve `<libname>::…` paths
    against the library crate.
    """
    segs = list(segments)
    if not segs:
        return EXTERNAL, None
    head = segs[0]
    if head == "crate":
        return _resolve_in(index, index.root, segs[1:])
    if lib_index is not None and head == lib_index.crate_name:
        return _resolve_in(lib_index, lib_index.root, segs[1:])
    if index is not None and head == index.crate_name:
        return _resolve_in(index, index.root, segs[1:])
    return EXTERNAL, None


def _resolve_in(index, module, segs, depth=0):
    if depth > 16:  # re-export cycle guard
        return UNRESOLVED, None
    if not segs:
        return RESOLVED, module
    cur = module
    for k, seg in enumerate(segs):
        last = k == len(segs) - 1
        rest = segs[k + 1 :]
        if seg in ("self",):
            continue
        if seg in cur.submodules:
            cur = cur.submodules[seg]
            if last:
                return RESOLVED, cur
            continue
        if seg in cur.items:
            item = cur.items[seg]
            if last:
                return RESOLVED, item
            # Enum::Variant is the only multi-segment item path in use
            # decls this subset accepts.
            if len(rest) == 1 and item.kind == "enum" and rest[0] in item.variants:
                return RESOLVED, item
            return UNRESOLVED, None
        # named re-exports
        rex = next((r for r in cur.reexports if not r.glob and r.name == seg), None)
        if rex is not None:
            status, obj = _resolve_relative(index, cur, rex.target, depth + 1)
            if status != RESOLVED:
                return status, None
            if last:
                return RESOLVED, obj
            if isinstance(obj, Module):
                cur = obj
                continue
            return UNRESOLVED, None
        # glob re-exports: try each target module
        saw_external = False
        for r in (r for r in cur.reexports if r.glob):
            status, obj = _resolve_relative(index, cur, r.target, depth + 1)
            if status == EXTERNAL:
                saw_external = True
                continue
            if status == RESOLVED and isinstance(obj, Module):
                status2, obj2 = _resolve_in(index, obj, segs[k:], depth + 1)
                if status2 == RESOLVED:
                    return status2, obj2
        if saw_external:
            return EXTERNAL, None
        return UNRESOLVED, None
    return RESOLVED, cur


def _resolve_relative(index, module, target, depth):
    """Resolve a re-export target written relative to `module`."""
    segs = list(target)
    if not segs:
        return UNRESOLVED, None
    if segs[0] == "crate":
        return _resolve_in(index, index.root, segs[1:], depth)
    if segs[0] == "self":
        return _resolve_in(index, module, segs[1:], depth)
    if segs[0] == "super":
        # parents aren't tracked on Module; resolve supers from the root
        # by path — conservatively treat as external (repo doesn't use
        # `pub use super::…`).
        return EXTERNAL, None
    # 2018 edition: a bare leading segment names a sibling submodule or
    # item of `module`; otherwise it is an external crate.
    if segs[0] in module.submodules or segs[0] in module.items:
        return _resolve_in(index, module, segs, depth)
    return EXTERNAL, None


def is_test_only(obj):
    if isinstance(obj, Module):
        return obj.test_only
    if isinstance(obj, Item):
        return obj.cfg.test_only
    return False
