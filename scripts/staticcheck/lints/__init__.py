"""Lint registry. Each lint module exposes `NAME` and `run(repo)`."""

from . import modpath, features, panics, consistency, concurrency

ALL_LINTS = [modpath, features, panics, consistency, concurrency]
