"""Lint registry. Each lint module exposes `NAME` and `run(repo)`."""

from . import (
    modpath, features, panics, consistency, concurrency,
    panic_reach, oracle_parity,
)

ALL_LINTS = [
    modpath, features, panics, consistency, concurrency,
    panic_reach, oracle_parity,
]
