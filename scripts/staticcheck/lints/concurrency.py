"""Lint 5 — concurrency audit over the coordinator.

Two checks:

1. **Lock-order inversions.** Records, per function across
   `rust/src/coordinator/*.rs`, the order in which named locks are
   acquired — `<name>.lock()` for mutexes plus zero-argument
   `<name>.read()` / `<name>.write()` for RwLocks (the zero-argument
   requirement keeps `io::Read::read(&mut buf)` and
   `Write::write(&bytes)` out); first occurrence each. Any cycle in the
   resulting global acquisition-order graph — `a` before `b` in one
   function, `b` before `a` in another — is a potential deadlock and is
   flagged. Read and write guards on the same RwLock count as the same
   lock: read/read cannot deadlock on its own, but a writer arriving
   between two readers can under writer-preferring fairness, so the
   conservative merge is intentional. Guard lifetimes are not modeled
   either; waive a provably-released pair with
   `// staticcheck: allow(concurrency, "…")` on the later acquisition.

2. **Relaxed reads in `Metrics::snapshot`.** The snapshot-coherence
   contract wants `Ordering::Acquire` loads in `snapshot()` so a
   reader that observes a bumped counter also observes the writes that
   preceded the bump; `Ordering::Relaxed` there is flagged.
"""

from ..report import Finding, collect_waivers, apply_waivers, finish_waivers
from ..tokenizer import code_tokens, match_brace

NAME = "concurrency"
CATEGORY = "concurrency"

COORD_GLOB = "rust/src/coordinator/*.rs"


def run(repo):
    findings = []
    edges = {}  # (a, b) -> (path, line, fn_name) of the b-acquisition
    waivers_by_file = {}
    for rel in repo.glob(COORD_GLOB):
        text = repo.read(rel)
        all_toks = repo.tokens(rel)
        waivers, waiver_errors = collect_waivers(text, all_toks)
        waivers_by_file[rel] = waivers
        for line, msg in waiver_errors:
            findings.append(Finding(NAME, CATEGORY, rel, line, msg))
        toks = code_tokens(all_toks)
        file_findings = []
        for fn_name, lo, hi in _functions(toks):
            seq = _lock_sequence(toks, lo, hi)
            for ai in range(len(seq)):
                for bi in range(ai + 1, len(seq)):
                    a, (b, line) = seq[ai][0], (seq[bi][0], seq[bi][1])
                    if a != b and (a, b) not in edges:
                        edges[(a, b)] = (rel, line, fn_name)
            if fn_name == "snapshot":
                file_findings.extend(_relaxed_loads(toks, lo, hi, rel))
        apply_waivers(file_findings, waivers)
        findings.extend(file_findings)

    # Cycle findings span files, so their waivers can only be applied
    # once every file's edges are in — match each against the waivers of
    # the file its reported acquisition sits in.
    cycle_findings = _order_cycles(edges)
    for f in cycle_findings:
        apply_waivers([f], waivers_by_file.get(f.path, []))
    findings.extend(cycle_findings)

    for rel, waivers in waivers_by_file.items():
        findings.extend(finish_waivers(repo, NAME, CATEGORY, rel, waivers))
    return findings


def _functions(toks):
    """Yield (name, body_lo, body_hi) for every fn in the token stream."""
    i, n = 0, len(toks)
    while i < n:
        t = toks[i]
        if t.kind == "ident" and t.value == "fn" and i + 1 < n and toks[i + 1].kind == "ident":
            name = toks[i + 1].value
            j = i + 2
            par = brk = 0
            while j < n:
                v = toks[j].value if toks[j].kind == "punct" else ""
                if v == "(":
                    par += 1
                elif v == ")":
                    par -= 1
                elif v == "[":
                    brk += 1
                elif v == "]":
                    brk -= 1
                elif v == "{" and par == 0 and brk == 0:
                    end = match_brace(toks, j)
                    yield name, j + 1, end
                    break
                elif v == ";" and par == 0 and brk == 0:
                    break  # trait method declaration, no body
                j += 1
            i = j
        i += 1


ACQUIRE_METHODS = frozenset(["lock", "read", "write"])


def _lock_sequence(toks, lo, hi):
    """First-acquisition order of named Mutex/RwLock guards in a body.

    Only zero-argument calls count — `Mutex::lock()`, `RwLock::read()`,
    `RwLock::write()` all take no arguments, while the `io::Read` /
    `io::Write` methods that share the `read`/`write` names take a
    buffer.
    """
    seen, seq = set(), []
    for i in range(lo, hi):
        t = toks[i]
        if (
            t.kind == "ident" and t.value in ACQUIRE_METHODS
            and i > 1 and toks[i - 1].kind == "punct" and toks[i - 1].value == "."
            and i + 2 < hi
            and toks[i + 1].kind == "punct" and toks[i + 1].value == "("
            and toks[i + 2].kind == "punct" and toks[i + 2].value == ")"
            and toks[i - 2].kind == "ident"
        ):
            name = toks[i - 2].value
            if name not in seen:
                seen.add(name)
                seq.append((name, t.line))
    return seq


def _relaxed_loads(toks, lo, hi, rel):
    out = []
    for i in range(lo, hi):
        t = toks[i]
        if t.kind == "ident" and t.value == "Relaxed":
            out.append(
                Finding(NAME, CATEGORY, rel, t.line,
                        "Ordering::Relaxed read inside Metrics::snapshot —"
                        " the snapshot-coherence contract wants Acquire")
            )
    return out


def _order_cycles(edges):
    """Flag every edge that participates in an acquisition-order cycle."""
    graph = {}
    for (a, b) in edges:
        graph.setdefault(a, set()).add(b)

    def reaches(src, dst):
        stack, seen = [src], set()
        while stack:
            x = stack.pop()
            if x == dst:
                return True
            if x in seen:
                continue
            seen.add(x)
            stack.extend(graph.get(x, ()))
        return False

    out, reported = [], set()
    for (a, b), (rel, line, fn_name) in sorted(edges.items()):
        if frozenset((a, b)) in reported:
            continue
        if reaches(b, a):
            reported.add(frozenset((a, b)))
            out.append(
                Finding(NAME, CATEGORY, rel, line,
                        f"lock-order inversion: `{a}` is acquired before"
                        f" `{b}` in fn {fn_name}, but a path acquires them"
                        " in the opposite order")
            )
    return out
