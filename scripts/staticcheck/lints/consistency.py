"""Lint 4 — cross-layer consistency.

Three agreements that live in different files and drift independently:

1. `configs/*.toml` ↔ `rust/src/config.rs`: every section and key a
   shipped config uses must appear as a string literal in config.rs —
   a key the parser never names is silently ignored at load time.
2. CLI flags: the `--flag` set parsed by `rust/src/main.rs`
   (`req`/`opt`/`opt_parse`/`opt_some`/`has` call sites and the
   boolean-flag lists passed to `Args::parse`) must match the `--flag`
   set the README documents, in both directions. README lines invoking
   other tools (`cargo …`, `aot.py …`) are exempt.
3. `.rlsh` persistence ↔ README: every format version constant
   (`MAGIC_Vn`) and every checksummed section name that
   `rust/src/index/persist.rs` verifies must be mentioned in README.
"""

import re

from ..report import Finding
from ..tokenizer import code_tokens

NAME = "cross-layer"
CATEGORY = "consistency"

CONFIG_RS = "rust/src/config.rs"
MAIN_RS = "rust/src/main.rs"
PERSIST_RS = "rust/src/index/persist.rs"
README = "README.md"

ARG_METHODS = frozenset(["req", "opt", "opt_parse", "opt_some", "has"])
README_FLAG_RE = re.compile(r"--([a-z][a-z0-9-]*)")
# README lines whose --flags belong to other tools, not our CLI.
FOREIGN_TOOL_RE = re.compile(
    r"\bcargo\b|\baot\.py\b|\bpython3?\b|\bcompile\.aot\b|\bcheck\.py\b|\bpip\b|\bgit\b"
)


def run(repo):
    findings = []
    findings.extend(_check_configs(repo))
    findings.extend(_check_cli_flags(repo))
    findings.extend(_check_persistence(repo))
    return findings


# -- 1: configs ↔ config.rs -----------------------------------------------


def _check_configs(repo):
    cfg_rs = repo.read(CONFIG_RS)
    files = repo.config_files()
    if cfg_rs is None or not files:
        return []
    literals = {
        t.value.strip('"')
        for t in code_tokens(repo.tokens(CONFIG_RS))
        if t.kind == "str"
    }
    out = []
    for rel in files:
        section = ""
        for lineno, raw in enumerate((repo.read(rel) or "").splitlines(), 1):
            s = raw.split("#", 1)[0].strip()
            if not s:
                continue
            if s.startswith("[") and s.endswith("]"):
                section = s[1:-1].strip()
                if section not in literals:
                    out.append(
                        Finding(NAME, CATEGORY, rel, lineno,
                                f"section [{section}] is never named by"
                                f" {CONFIG_RS}")
                    )
                continue
            if "=" in s:
                key = s.split("=", 1)[0].strip()
                if key not in literals:
                    out.append(
                        Finding(NAME, CATEGORY, rel, lineno,
                                f"[{section}] key `{key}` is never parsed by"
                                f" {CONFIG_RS} — it would be silently ignored")
                    )
    return out


# -- 2: CLI flags ↔ README -------------------------------------------------


def _main_rs_flags(repo):
    """flag -> first definition line in main.rs."""
    toks = code_tokens(repo.tokens(MAIN_RS))
    flags = {}
    i, n = 0, len(toks)
    while i < n:
        t = toks[i]
        if t.kind == "ident" and t.value in ARG_METHODS:
            j = i + 1
            # optional turbofish ::<…>
            if j + 1 < n and toks[j].value == ":" and toks[j + 1].value == ":":
                j += 2
                if j < n and toks[j].value == "<":
                    depth = 0
                    while j < n:
                        if toks[j].value == "<":
                            depth += 1
                        elif toks[j].value == ">":
                            depth -= 1
                            if depth == 0:
                                j += 1
                                break
                        j += 1
            if j < n and toks[j].kind == "punct" and toks[j].value == "(":
                if j + 1 < n and toks[j + 1].kind == "str":
                    name = toks[j + 1].value.strip('"')
                    flags.setdefault(name, toks[j + 1].line)
        # boolean-flag lists: Args::parse(rest, &["compare", …])
        if t.kind == "ident" and t.value == "parse" and i + 1 < n and toks[i + 1].value == "(":
            depth, j = 0, i + 1
            in_list = False
            while j < n:
                v = toks[j].value if toks[j].kind == "punct" else ""
                if v == "(":
                    depth += 1
                elif v == ")":
                    depth -= 1
                    if depth == 0:
                        break
                elif v == "[":
                    in_list = True
                elif v == "]":
                    in_list = False
                elif in_list and toks[j].kind == "str":
                    name = toks[j].value.strip('"')
                    flags.setdefault(name, toks[j].line)
                j += 1
        i += 1
    return flags


def _readme_flags(repo):
    """flag -> first mention line in README."""
    flags = {}
    for lineno, line in enumerate((repo.read(README) or "").splitlines(), 1):
        if FOREIGN_TOOL_RE.search(line):
            continue
        for m in README_FLAG_RE.finditer(line):
            flags.setdefault(m.group(1), lineno)
    return flags


def _check_cli_flags(repo):
    if repo.read(MAIN_RS) is None or repo.read(README) is None:
        return []
    impl = _main_rs_flags(repo)
    docs = _readme_flags(repo)
    out = []
    for flag, line in sorted(impl.items()):
        if flag not in docs:
            out.append(
                Finding(NAME, CATEGORY, MAIN_RS, line,
                        f"CLI flag --{flag} is parsed here but undocumented"
                        " in README.md")
            )
    for flag, line in sorted(docs.items()):
        if flag not in impl:
            out.append(
                Finding(NAME, CATEGORY, README, line,
                        f"README documents --{flag}, which main.rs does not"
                        " parse")
            )
    return out


# -- 3: persistence tags ↔ README -----------------------------------------


def _check_persistence(repo):
    persist = repo.read(PERSIST_RS)
    readme = repo.read(README)
    if persist is None or readme is None:
        return []
    out = []
    versions = sorted(set(re.findall(r"MAGIC_V(\d+)", persist)))
    if versions and ".rlsh" not in readme:
        out.append(
            Finding(NAME, CATEGORY, README, 1,
                    "README never mentions the .rlsh persistence format")
        )
    for v in versions:
        if not re.search(rf"\bv{v}\b", readme):
            out.append(
                Finding(NAME, CATEGORY, PERSIST_RS, _line_of(persist, f"MAGIC_V{v}"),
                        f".rlsh format v{v} exists in persist.rs but README"
                        " never mentions it")
            )
    toks = code_tokens(repo.tokens(PERSIST_RS))
    sections = {}
    for i, t in enumerate(toks):
        if (
            t.kind == "ident" and t.value == "verify_section_crc"
            and i + 2 < len(toks) and toks[i + 1].value == "(" and toks[i + 2].kind == "str"
        ):
            sections.setdefault(toks[i + 2].value.strip('"'), toks[i + 2].line)
    for name, line in sorted(sections.items()):
        if name not in readme:
            out.append(
                Finding(NAME, CATEGORY, PERSIST_RS, line,
                        f'checksummed section "{name}" is not described in'
                        " the README persistence section")
            )
    return out


def _line_of(text, needle):
    for i, line in enumerate(text.splitlines(), 1):
        if needle in line:
            return i
    return 1
