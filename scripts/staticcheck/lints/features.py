"""Lint 2 — feature-gate coherence.

Two contracts:

1. Every `feature = "name"` mentioned in a `#[cfg(…)]` / `#[cfg_attr(…)]`
   across the Rust sources names a feature declared in Cargo.toml
   `[features]` — a typo'd gate silently compiles the code out (or in)
   forever.
2. `#[cfg(test)]`-only items are never referenced from non-test code:
   a `use` outside a test scope that resolves to a test-only item or
   module would not compile under `cargo build`.
"""

from ..items import resolve_path, RESOLVED, is_test_only
from ..report import Finding

NAME = "feature-gates"
CATEGORY = "features"


def run(repo):
    findings = []
    declared = repo.cargo_features()
    lib = repo.lib_index()

    indices = []
    if lib is not None:
        indices.append((lib, None))
    for _, idx in repo.aux_indices():
        if idx is not None:
            indices.append((idx, lib))

    for idx, lib_idx in indices:
        if declared is not None:
            for path, line, feat in idx.cfg_features:
                if feat not in declared:
                    findings.append(
                        Finding(
                            NAME, CATEGORY, path, line,
                            f'cfg references feature "{feat}" not declared in'
                            " Cargo.toml [features]",
                        )
                    )
        for use in idx.all_uses():
            if use.in_test:
                continue
            status, obj = resolve_path(idx, use.segments, lib_index=lib_idx)
            if status == RESOLVED and is_test_only(obj):
                findings.append(
                    Finding(
                        NAME, CATEGORY, use.path, use.line,
                        f"non-test code imports cfg(test)-only item"
                        f" `{'::'.join(use.segments)}`",
                    )
                )
    return findings
