"""Lint 1 — module/path resolution.

Every `mod foo;` must map to a backing file (`foo.rs` or `foo/mod.rs`
next to the declaring module), and every `use crate::…` path — plus
`use rangelsh::…` in the bin/test/bench/example crates — must resolve
to a declared item, module, re-export, or enum variant. Paths into
external crates (`std`, vendored `anyhow`, …) are out of scope.
"""

from ..items import resolve_path, RESOLVED, UNRESOLVED
from ..report import Finding

NAME = "mod-path"
CATEGORY = "modpath"


def run(repo):
    findings = []
    lib = repo.lib_index()
    indices = []
    if lib is not None:
        indices.append((lib, None))
    for _, idx in repo.aux_indices():
        if idx is not None:
            indices.append((idx, lib))

    for idx, lib_idx in indices:
        for path, line, msg in idx.problems:
            findings.append(Finding(NAME, CATEGORY, path, line, msg))
        for use in idx.all_uses():
            status, _ = resolve_path(idx, use.segments, lib_index=lib_idx)
            if status == UNRESOLVED:
                findings.append(
                    Finding(
                        NAME, CATEGORY, use.path, use.line,
                        f"use path `{'::'.join(use.segments)}` does not resolve"
                        " to any declared item",
                    )
                )
    return findings
