"""Lint 7 — oracle parity: every fast path is pinned to its kept oracle.

Every optimized read path in this repo ships next to a bit-identical
reference implementation (PRs 2–6): lazy probing vs the eager counting
sort, MIH Hamming walks vs the counting sort, the streaming re-rank vs
the exhaustive scorer, blocked hashing vs per-item hashing. The
equivalence only means something while a test actually exercises *both*
members of each pair — an edit that quietly drops one side of a
property test would leave the oracle dead code and the claim unchecked.

The manifest `scripts/staticcheck/oracle_pairs.json` declares the
pairs:

    {"pairs": [{"name": "...", "fast": "Type::fn", "oracle": "Type::fn"}]}

(a bare `fn` name declares a free function). For each pair this lint
requires, via the merged lib + test-crate call graph, at least one
`#[test]` function whose reachable set contains both members — the test
is named in `--list-waived`-style reports and pinned by the pytest
suite. Findings:

- a manifest member that resolves to no function in the lib crate;
- a pair no single test reaches both members of;
- a non-test lib function whose name carries an oracle-style suffix
  (`_oracle`, `_eager`, `_unblocked`) but appears in no manifest pair —
  an undeclared oracle. Waivable at the `fn` line with
  `// staticcheck: allow(oracle-parity, "<reason>")`.
"""

import fnmatch
import json

from ..report import Finding, collect_waivers, finish_waivers
from ..repo import LIB_ROOT

NAME = "oracle-parity"
CATEGORY = "oracle-parity"

MANIFEST = "scripts/staticcheck/oracle_pairs.json"
ORACLE_SUFFIXES = ("_oracle", "_eager", "_unblocked")


def load_manifest(repo):
    text = repo.read(MANIFEST)
    if text is None:
        return []
    return json.loads(text).get("pairs", [])


def _resolve_member(graph, spec):
    """Lib-crate node ids a manifest member spec names."""
    if "::" in spec:
        owner, name = spec.rsplit("::", 1)
        ids = [
            i for i in graph.by_name.get(name, ())
            if graph.nodes[i].crate == LIB_ROOT
            and (graph.nodes[i].self_type or graph.nodes[i].trait_name) == owner
        ]
    else:
        ids = [
            i for i in graph.free_by_name.get(spec, ())
            if graph.nodes[i].crate == LIB_ROOT
        ]
    return ids


def match_pairs(repo):
    """pair name -> (matched test qname or None, pair dict).

    The lint's core; exposed so the test suite can pin every real-repo
    pair to a concrete named test (non-vacuity).
    """
    pairs = load_manifest(repo)
    graph = repo.call_graph([LIB_ROOT] + repo.test_crate_roots())
    # Deterministic order, dedicated test crates ahead of inline
    # `mod tests` units: the cross-member equivalence properties live in
    # `tests/*.rs`, and conservative fan-out makes "reaches" generous
    # enough that some unit test usually reaches too.
    tests = sorted(
        (n for n in graph.nodes if n.is_test),
        key=lambda n: (n.crate == LIB_ROOT, n.file, n.line),
    )
    reach_cache = {}

    def reachable(test_id):
        if test_id not in reach_cache:
            reach_cache[test_id] = set(graph.reachable_from([test_id]))
        return reach_cache[test_id]

    out = {}
    for pair in pairs:
        fast = set(_resolve_member(graph, pair["fast"]))
        oracle = set(_resolve_member(graph, pair["oracle"]))
        matched = None
        if fast and oracle:
            # An optional `test` fnmatch pattern names the test(s) that
            # are allowed to witness the pair — without it, any test
            # counts, and fan-out noise can match vacuously.
            pat = pair.get("test", "*")
            for t in tests:
                if not fnmatch.fnmatch(t.name, pat):
                    continue
                r = reachable(t.id)
                if r & fast and r & oracle:
                    matched = t.qname
                    break
        out[pair["name"]] = (matched, pair, bool(fast), bool(oracle))
    return out


def run(repo):
    graph = repo.lib_graph()
    if not graph.nodes:
        return []  # no library crate in this tree
    pairs = load_manifest(repo)
    findings = []

    manifest_members = set()
    for pair in pairs:
        manifest_members.add(pair["fast"])
        manifest_members.add(pair["oracle"])

    if pairs:
        for name, (matched, pair, fast_ok, oracle_ok) in match_pairs(repo).items():
            for member, ok in ((pair["fast"], fast_ok), (pair["oracle"], oracle_ok)):
                if not ok:
                    findings.append(
                        Finding(
                            NAME, CATEGORY, MANIFEST, 0,
                            f"pair `{name}`: member `{member}` resolves to no"
                            " function in the library crate",
                        )
                    )
            if fast_ok and oracle_ok and matched is None:
                pat = pair.get("test", "*")
                scope = f" matching `{pat}`" if pat != "*" else ""
                findings.append(
                    Finding(
                        NAME, CATEGORY, MANIFEST, 0,
                        f"pair `{name}`: no single test{scope} has a call graph"
                        f" reaching both `{pair['fast']}` and `{pair['oracle']}`"
                        " — the parity property is unverified",
                    )
                )

    # Undeclared oracles: suffix-named lib functions outside the manifest.
    suffix_nodes = [
        n for n in graph.nodes
        if not n.test_only
        and n.crate == LIB_ROOT
        and n.name.endswith(ORACLE_SUFFIXES)
    ]
    waivers_by_file = {}
    for n in suffix_nodes:
        if n.qname in manifest_members or n.name in manifest_members:
            continue
        if n.file not in waivers_by_file:
            text, toks = repo.read(n.file), repo.tokens(n.file)
            ws, werrs = collect_waivers(text or "", toks or [])
            waivers_by_file[n.file] = [w for w in ws if w.category == CATEGORY]
            for line, msg in werrs:
                findings.append(Finding(NAME, CATEGORY, n.file, line, msg))
        waiver = next(
            (w for w in waivers_by_file[n.file] if w.covers(n.line)), None
        )
        f = Finding(
            NAME, CATEGORY, n.file, n.line,
            f"fn `{n.qname}` looks like a kept oracle (suffix) but no"
            " oracle_pairs.json pair declares it — parity is unchecked",
        )
        if waiver is not None:
            f.waived, f.waive_reason, waiver.used = True, waiver.reason, True
        findings.append(f)

    # live/stale bookkeeping for oracle-parity waivers seen above
    for rel, ws in waivers_by_file.items():
        findings.extend(finish_waivers(repo, NAME, CATEGORY, rel, ws))
    return findings
