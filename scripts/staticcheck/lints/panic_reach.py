"""Lint 6 — interprocedural panic reachability from the serving surface.

The degraded-serving contract (PR 7) and the equal-recall speedup claim
both assume the serving path never panics: `catch_unwind` would report
an accidental panic as shard loss, and a panic inside `.rlsh` load/save
turns a corrupt-file error into a crash. `panic-path` (lint 3) checks
the four coordinator modules line by line; this lint closes the
transitive gap: starting from the serving entry points

    ServerHandle::query*   ShardedRouter::query*
    AnyEngine::search*     SearchEngine::search*
    save_range_index       load_range_index     load_any_range_index

it walks the whole-crate call graph (`staticcheck/callgraph.py` —
conservative trait fan-out, unresolved receivers over-approximate) and
flags every reachable non-test function in `index/`, `hash/`, `data/`,
`util/` (anywhere outside the four panic-path files) that contains a
may-panic construct: `unwrap`/`expect`, a panicking macro, or a bare
index/slice expression. Each finding reports a shortest witness path
from an entry point.

A function the review has bounds-checked is waived *at the function
level* — the waiver sits on (or directly above) its `fn` line and
covers every site in the body:

    // staticcheck: allow(panic-reach, "<why no site in here can fire>")
    pub fn counting_sort_partial(&self, …)

A `panic-reach` waiver anchored to a function that no longer contains
any may-panic construct is stale and becomes a finding itself.
"""

import fnmatch

from ..report import Finding, collect_waivers
from .panics import SERVING_FILES

NAME = "panic-reach"
CATEGORY = "panic-reach"

ENTRY_PATTERNS = [
    "ServerHandle::query*",
    "ServerHandle::ingest",
    "ServerHandle::delete",
    "ServerHandle::mutate",
    "ShardedRouter::query*",
    "ShardedRouter::ingest",
    "ShardedRouter::delete",
    "AnyEngine::search*",
    "SearchEngine::search*",
    "save_range_index",
    "load_range_index",
    "load_any_range_index",
    # PR 10: the WAL-backed mutable store. Every mutation/compaction/
    # recovery entry is a serving entry — a panic inside WAL replay or
    # checkpointing turns a recoverable crash into an unrecoverable one.
    "MutableStore::*",
    "AnyStore::*",
    "Wal::*",
    "load_manifest",
    "save_manifest",
]

SERVING = frozenset(SERVING_FILES)


def entry_ids(graph):
    return [
        n.id
        for n in graph.nodes
        if not n.test_only
        and any(fnmatch.fnmatch(n.qname, p) for p in ENTRY_PATTERNS)
    ]


def analyze(repo):
    """(graph, parent map, [panicking reachable nodes]) for the lib crate.

    Exposed separately so the test suite can pin non-vacuity (entry
    count, reachable-set size) without re-deriving the BFS.
    """
    graph = repo.lib_graph()
    entries = entry_ids(graph)
    parent = graph.reachable_from(entries, node_filter=lambda n: not n.test_only)
    flagged = [
        graph.nodes[i]
        for i in parent
        if graph.nodes[i].panics
        and not graph.nodes[i].test_only
        and graph.nodes[i].file not in SERVING
    ]
    flagged.sort(key=lambda n: (n.file, n.line))
    return graph, parent, flagged


def run(repo):
    graph = repo.lib_graph()
    if not graph.nodes:
        return []  # no library crate in this tree
    graph, parent, flagged = analyze(repo)

    # Function-level waivers, gathered per file that defines functions.
    findings = []
    waivers_by_file = {}
    for rel in sorted({n.file for n in graph.nodes}):
        text = repo.read(rel)
        toks = repo.tokens(rel)
        if text is None or toks is None:
            continue
        waivers, waiver_errors = collect_waivers(text, toks)
        mine = [w for w in waivers if w.category == CATEGORY]
        waivers_by_file[rel] = mine
        for line, msg in waiver_errors:
            findings.append(Finding(NAME, CATEGORY, rel, line, msg))

    # A waiver is *live* when the function it anchors still contains a
    # may-panic construct — reachable or not. (An unreachable panicking
    # fn keeps its waiver: the construct the reason argues about is
    # still there, and reachability can silently return as call sites
    # move.)
    panicking_lines = {}
    for n in graph.nodes:
        if n.panics:
            panicking_lines.setdefault(n.file, set()).add(n.line)

    for node in flagged:
        waiver = next(
            (w for w in waivers_by_file.get(node.file, ()) if w.covers(node.line)),
            None,
        )
        site = node.panics[0]
        more = f" (+{len(node.panics) - 1} more site(s))" if len(node.panics) > 1 else ""
        msg = (
            f"fn `{node.qname}` may panic — {site.what} at line {site.line}{more} —"
            f" and is reachable from a serving entry point:"
            f" {graph.format_path(parent, node.id)}"
        )
        f = Finding(NAME, CATEGORY, node.file, node.line, msg)
        if waiver is not None:
            f.waived, f.waive_reason, waiver.used = True, waiver.reason, True
        findings.append(f)

    # Stale waivers + the shared live/stale log for --list-waived.
    for rel, mine in waivers_by_file.items():
        for w in mine:
            live = any(w.covers(line) for line in panicking_lines.get(rel, ()))
            repo.log_waiver(rel, w, live)
            if not live:
                findings.append(
                    Finding(
                        NAME, CATEGORY, rel, w.line,
                        f"stale waiver: allow({CATEGORY}, \"{w.reason}\") anchors"
                        " a function with no remaining may-panic construct",
                    )
                )
    return findings
