"""Lint 3 — panic paths in the serving coordinator.

PR 7's degraded-serving contract routes *injected* shard panics through
`catch_unwind` and treats them as shard loss; an accidental panic on the
serving path is therefore silently misreported as infrastructure
failure instead of crashing loudly in development. Inside the four
serving modules, `unwrap()` / `expect()` / `unwrap_err()` /
`expect_err()`, the panicking macros (`panic!`, `unreachable!`, `todo!`,
`unimplemented!`), and bare index/slice expressions (`xs[i]`,
`&rows[lo..hi]`) are forbidden unless annotated

    // staticcheck: allow(panic, "<why this cannot fire>")

`#[cfg(test)]` items (including inline `mod tests`) are exempt: they
never ship, and tests *should* unwrap.
"""

from ..items import make_cfg, _match_bracket, _skip_to_body_or_semi
from ..report import Finding, collect_waivers, apply_waivers, finish_waivers
from ..tokenizer import code_tokens, KEYWORDS

NAME = "panic-path"
CATEGORY = "panic"

SERVING_FILES = [
    "rust/src/coordinator/engine.rs",
    "rust/src/coordinator/router.rs",
    "rust/src/coordinator/server.rs",
    "rust/src/coordinator/batcher.rs",
]

PANIC_METHODS = frozenset(["unwrap", "expect", "unwrap_err", "expect_err"])
PANIC_MACROS = frozenset(["panic", "unreachable", "todo", "unimplemented"])


def run(repo):
    findings = []
    for rel in SERVING_FILES:
        text = repo.read(rel)
        if text is None:
            continue
        all_toks = repo.tokens(rel)
        waivers, waiver_errors = collect_waivers(text, all_toks)
        for line, msg in waiver_errors:
            findings.append(Finding(NAME, CATEGORY, rel, line, msg))
        file_findings = _scan(code_tokens(all_toks), rel)
        apply_waivers(file_findings, waivers)
        findings.extend(file_findings)
        findings.extend(finish_waivers(repo, NAME, CATEGORY, rel, waivers))
    return findings


def _scan(toks, rel):
    out = []
    i, n = 0, len(toks)
    while i < n:
        t = toks[i]
        # Attributes: capture cfg; a test-only item is skipped wholesale.
        if t.kind == "punct" and t.value == "#":
            j = i + 1
            if j < n and toks[j].kind == "punct" and toks[j].value == "!":
                j += 1
            if j < n and toks[j].kind == "punct" and toks[j].value == "[":
                end = _match_bracket(toks, j, n)
                attr = " ".join(tk.value for tk in toks[i : end + 1])
                if make_cfg([attr]).test_only:
                    k = end + 1
                    # further attributes on the same item
                    while k < n and toks[k].kind == "punct" and toks[k].value == "#":
                        k2 = k + 1
                        if k2 < n and toks[k2].value == "[":
                            k = _match_bracket(toks, k2, n) + 1
                        else:
                            break
                    i = _skip_to_body_or_semi(toks, k, n)
                    continue
                i = end + 1
                continue
        if t.kind == "ident":
            nxt = toks[i + 1] if i + 1 < n else None
            prv = toks[i - 1] if i > 0 else None
            if (
                t.value in PANIC_METHODS
                and prv is not None and prv.kind == "punct" and prv.value == "."
                and nxt is not None and nxt.kind == "punct" and nxt.value == "("
            ):
                out.append(
                    Finding(NAME, CATEGORY, rel, t.line,
                            f".{t.value}() on the serving path can panic")
                )
            elif (
                t.value in PANIC_MACROS
                and nxt is not None and nxt.kind == "punct" and nxt.value == "!"
            ):
                out.append(
                    Finding(NAME, CATEGORY, rel, t.line,
                            f"{t.value}! on the serving path")
                )
        elif t.kind == "punct" and t.value == "[" and i > 0:
            prv = toks[i - 1]
            is_index = (
                (prv.kind == "ident" and prv.value not in KEYWORDS)
                or (prv.kind == "punct" and prv.value in ")]")
                or prv.kind == "num"  # tuple-field slicing: x.0[..]
            )
            if is_index:
                out.append(
                    Finding(NAME, CATEGORY, rel, t.line,
                            "bare index/slice expression can panic on the"
                            " serving path")
                )
        i += 1
    return out
