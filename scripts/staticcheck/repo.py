"""RepoContext — lazily loaded view of everything the lints read.

One instance is shared by every lint in a `scripts/check.py` run so
files are read and tokenized once. All accessors tolerate a missing
file (tests point the context at minimal fixture trees that only carry
what one lint needs); lints skip the checks whose inputs are absent.
"""

import re
from pathlib import Path

from . import items
from .tokenizer import tokenize

LIB_ROOT = "rust/src/lib.rs"
BIN_ROOT = "rust/src/main.rs"
CRATE_NAME = "rangelsh"


class RepoContext:
    def __init__(self, root):
        self.root = Path(root)
        self._texts = {}
        self._tokens = {}
        self._indices = {}
        self._graphs = {}
        # (path, line) -> {"category", "reason", "live"} — filled by the
        # lints as they apply waivers; `check.py --list-waived` reads it.
        self.waiver_log = {}

    def log_waiver(self, rel, waiver, live):
        key = (rel, waiver.line)
        prev = self.waiver_log.get(key)
        self.waiver_log[key] = {
            "category": waiver.category,
            "reason": waiver.reason,
            "live": live or (prev["live"] if prev else False),
        }

    # -- file access --------------------------------------------------

    def read(self, rel):
        """File text, or None when absent."""
        if rel not in self._texts:
            p = self.root / rel
            self._texts[rel] = p.read_text() if p.is_file() else None
        return self._texts[rel]

    def tokens(self, rel):
        """Full token stream (comments included), or None when absent."""
        if rel not in self._tokens:
            text = self.read(rel)
            self._tokens[rel] = None if text is None else tokenize(text)
        return self._tokens[rel]

    def glob(self, pattern):
        return sorted(
            str(p.relative_to(self.root)) for p in self.root.glob(pattern) if p.is_file()
        )

    # -- crate indices ------------------------------------------------

    @property
    def crate_name(self):
        return self._cargo_package_name() or CRATE_NAME

    def lib_index(self):
        """Item index of the library crate, or None when absent."""
        return self._index_for(LIB_ROOT)

    def aux_crate_roots(self):
        """Compilation roots other than the library: bin, tests, benches,
        examples. Each is its own crate whose `use <lib>::…` paths must
        resolve against the library index."""
        roots = []
        if (self.root / BIN_ROOT).is_file():
            roots.append(BIN_ROOT)
        for pat in ("tests/*.rs", "benches/*.rs", "examples/*.rs"):
            roots.extend(self.glob(pat))
        return roots

    def _index_for(self, rel):
        if rel not in self._indices:
            if not (self.root / rel).is_file():
                self._indices[rel] = None
            else:
                self._indices[rel] = items.build_crate_index(self.root, rel, self.crate_name)
        return self._indices[rel]

    def index_for(self, rel):
        return self._index_for(rel)

    def aux_indices(self):
        return [(r, self._index_for(r)) for r in self.aux_crate_roots()]

    # -- call graphs ---------------------------------------------------

    def call_graph(self, roots):
        """Merged CallGraph over the given crate roots, cached per set."""
        from . import callgraph

        key = tuple(sorted(roots))
        if key not in self._graphs:
            self._graphs[key] = callgraph.build_graph(self, list(key))
        return self._graphs[key]

    def lib_graph(self):
        """Call graph of the library crate alone."""
        return self.call_graph([LIB_ROOT])

    def test_crate_roots(self):
        return sorted(self.glob("tests/*.rs"))

    # -- Cargo.toml ----------------------------------------------------

    def _cargo_package_name(self):
        text = self.read("Cargo.toml")
        if text is None:
            return None
        in_pkg = False
        for line in text.splitlines():
            s = line.strip()
            if s.startswith("["):
                in_pkg = s == "[package]" or s == "[lib]"
                continue
            if in_pkg:
                m = re.match(r'name\s*=\s*"([^"]+)"', s)
                if m:
                    return m.group(1).replace("-", "_")
        return None

    def cargo_features(self):
        """Feature names declared in Cargo.toml [features], or None."""
        text = self.read("Cargo.toml")
        if text is None:
            return None
        feats, in_features = set(), False
        for line in text.splitlines():
            s = line.split("#", 1)[0].strip()
            if s.startswith("["):
                in_features = s == "[features]"
                continue
            if in_features:
                m = re.match(r'("?)([A-Za-z0-9_-]+)\1\s*=', s)
                if m:
                    feats.add(m.group(2))
        return feats

    # -- configs -------------------------------------------------------

    def config_files(self):
        return self.glob("configs/*.toml")

    def parse_toml_keys(self, rel):
        """section -> set of keys for a configs/*.toml file (the same
        TOML subset `rust/src/util/toml.rs` accepts)."""
        text = self.read(rel)
        out, section = {}, ""
        for line in (text or "").splitlines():
            s = line.split("#", 1)[0].strip()
            if not s:
                continue
            if s.startswith("[") and s.endswith("]"):
                section = s[1:-1].strip()
                out.setdefault(section, set())
                continue
            if "=" in s:
                out.setdefault(section, set()).add(s.split("=", 1)[0].strip())
        return out
