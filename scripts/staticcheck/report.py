"""Findings, waivers, and report formatting.

Waiver grammar (documented in README §"Static verification"):

    // staticcheck: allow(<category>, "<reason>")

- `<category>` names the lint family being waived (today: `panic`,
  `concurrency`).
- `<reason>` is mandatory and non-empty — an empty reason is itself a
  finding.
- A *trailing* waiver (code before the comment on the same line) covers
  findings on that line only. A *standalone* waiver comment covers
  findings on the next line of code. One waiver covers every finding of
  its category on the covered line.
"""

import re
from dataclasses import dataclass, field

WAIVER_RE = re.compile(
    r"staticcheck:\s*allow\(\s*([A-Za-z_-]+)\s*,\s*\"([^\"]*)\"\s*\)"
)


@dataclass
class Finding:
    lint: str  # lint name, e.g. "panic-path"
    category: str  # waiver category it answers to, e.g. "panic"
    path: str  # repo-relative file path
    line: int
    message: str
    waived: bool = False
    waive_reason: str = ""

    def format(self):
        mark = f"waived ({self.waive_reason})" if self.waived else "ERROR"
        return f"  {self.path}:{self.line}: [{self.lint}] {self.message} — {mark}"


@dataclass
class Waiver:
    category: str
    reason: str
    line: int  # line the waiver comment sits on
    standalone: bool  # True when the comment is the whole line
    used: bool = False

    def covers(self, line):
        return line == self.line or (self.standalone and line == self.line + 1)


def collect_waivers(text, toks):
    """Extract waivers from a file's comment tokens.

    `text` is the file source (to decide standalone vs trailing),
    `toks` the full token stream including comments. Malformed or
    reason-less waivers are returned as error findings alongside.
    """
    lines = text.split("\n")
    waivers, errors = [], []
    for t in toks:
        if t.kind != "comment":
            continue
        m = WAIVER_RE.search(t.value)
        if m is None:
            if "staticcheck:" in t.value:
                errors.append(
                    (t.line, "malformed staticcheck annotation (want "
                     'staticcheck: allow(<category>, "<reason>"))')
                )
            continue
        category, reason = m.group(1), m.group(2).strip()
        if not reason:
            errors.append((t.line, f"allow({category}, …) has an empty reason"))
            continue
        src_line = lines[t.line - 1] if t.line - 1 < len(lines) else ""
        standalone = src_line.strip().startswith("//")
        waivers.append(Waiver(category, reason, t.line, standalone))
    return waivers, errors


def apply_waivers(findings, waivers):
    """Mark findings covered by a matching-category waiver."""
    for f in findings:
        for w in waivers:
            if w.category == f.category and w.covers(f.line):
                f.waived = True
                f.waive_reason = w.reason
                w.used = True
                break
    return findings


def finish_waivers(repo, lint, category, rel, waivers):
    """Post-`apply_waivers` bookkeeping for one file's waivers.

    Records every waiver of the lint's own category in the repo-wide
    live/stale log (`check.py --list-waived`) and returns a finding for
    each *stale* one — a waiver whose anchored line no longer produces
    the finding it was written to cover survives edits silently
    otherwise, and a reason argued about vanished code is worse than no
    waiver at all.
    """
    out = []
    for w in waivers:
        if w.category != category:
            continue
        repo.log_waiver(rel, w, w.used)
        if not w.used:
            out.append(
                Finding(
                    lint, category, rel, w.line,
                    f"stale waiver: allow({category}, \"{w.reason}\") covers no"
                    f" finding on its anchored line",
                )
            )
    return out


@dataclass
class Report:
    findings: list = field(default_factory=list)

    def extend(self, fs):
        self.findings.extend(fs)

    @property
    def errors(self):
        return [f for f in self.findings if not f.waived]

    @property
    def waived(self):
        return [f for f in self.findings if f.waived]
