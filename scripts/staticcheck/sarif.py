"""SARIF 2.1.0 serialization of a staticcheck run.

`check.py --sarif <path>` writes one run per invocation so CI can
upload the findings to GitHub code scanning
(`github/codeql-action/upload-sarif`). Mapping:

- each lint module becomes a `rule` (id = lint NAME, short description
  = first line of its module docstring);
- each finding becomes a `result` at its file/line; unwaived findings
  are `level: error`, waived ones `level: note` with an in-source
  `suppression` carrying the waiver reason, so code scanning shows them
  as dismissed rather than open;
- manifest-level findings that carry line 0 (e.g. oracle-parity pair
  failures) are clamped to line 1 — SARIF regions are 1-based.
"""

import json

SCHEMA_URI = (
    "https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
    "master/Schemata/sarif-schema-2.1.0.json"
)


def to_sarif(findings, lints):
    """SARIF 2.1.0 log dict for a list of Findings and lint modules."""
    rules, rule_index = [], {}
    for lint in lints:
        doc = (lint.__doc__ or "").strip().splitlines()
        rule_index[lint.NAME] = len(rules)
        rules.append(
            {
                "id": lint.NAME,
                "shortDescription": {"text": doc[0] if doc else lint.NAME},
                "defaultConfiguration": {"level": "error"},
            }
        )

    results = []
    for f in findings:
        result = {
            "ruleId": f.lint,
            "level": "note" if f.waived else "error",
            "message": {"text": f.message},
            "locations": [
                {
                    "physicalLocation": {
                        "artifactLocation": {
                            "uri": f.path,
                            "uriBaseId": "%SRCROOT%",
                        },
                        "region": {"startLine": max(f.line, 1)},
                    }
                }
            ],
        }
        if f.lint in rule_index:
            result["ruleIndex"] = rule_index[f.lint]
        if f.waived:
            result["suppressions"] = [
                {"kind": "inSource", "justification": f.waive_reason}
            ]
        results.append(result)

    return {
        "$schema": SCHEMA_URI,
        "version": "2.1.0",
        "runs": [
            {
                "tool": {
                    "driver": {
                        "name": "staticcheck",
                        "rules": rules,
                    }
                },
                "results": results,
                "columnKind": "utf16CodeUnits",
            }
        ],
    }


def write_sarif(path, findings, lints):
    with open(path, "w") as fh:
        json.dump(to_sarif(findings, lints), fh, indent=2)
        fh.write("\n")
