"""Tokenizer for the Rust subset this repository uses.

Produces a flat token stream with line numbers. The goal is *lexical
fidelity*, not a grammar: downstream lints only need to know what is an
identifier, what is a string, what is a comment (comments carry the
`staticcheck: allow(...)` waivers), and where braces nest. Handles the
constructs that break naive regex scanning of Rust:

- line (`//`, `///`, `//!`) and nested block (`/* /* */ */`) comments
- string / raw-string / byte-string literals (`"…"`, `r#"…"#`, `b"…"`)
- char literals vs lifetimes (`'a'` vs `'a`)
- numeric literals with suffixes and `0..n` ranges (the `..` is not
  swallowed into the number)

Anything else is a single-character punct token.
"""

from dataclasses import dataclass

# Rust keywords that can precede `[` without forming an index
# expression (`let [a, b] = …`, `in [..]`, `return [..]`, …).
KEYWORDS = frozenset(
    """as async await box break const continue crate dyn else enum extern
    fn for if impl in let loop match mod move mut pub ref return self
    Self static struct super trait type union unsafe use where while
    yield""".split()
)


@dataclass
class Tok:
    kind: str  # ident | num | str | char | lifetime | punct | comment
    value: str
    line: int  # 1-based

    def __repr__(self):  # compact, for test failure messages
        return f"{self.kind}:{self.value!r}@{self.line}"


def _is_ident_start(c):
    return c.isalpha() or c == "_"


def _is_ident_cont(c):
    return c.isalnum() or c == "_"


def tokenize(text):
    """Tokenize Rust source `text` into a list of Tok."""
    toks = []
    i, n, line = 0, len(text), 1
    while i < n:
        c = text[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        # Comments.
        if c == "/" and i + 1 < n:
            nxt = text[i + 1]
            if nxt == "/":
                j = text.find("\n", i)
                if j == -1:
                    j = n
                toks.append(Tok("comment", text[i:j], line))
                i = j
                continue
            if nxt == "*":
                start, depth, j = i, 1, i + 2
                while j < n and depth:
                    if text.startswith("/*", j):
                        depth += 1
                        j += 2
                    elif text.startswith("*/", j):
                        depth -= 1
                        j += 2
                    else:
                        j += 1
                body = text[start:j]
                toks.append(Tok("comment", body, line))
                line += body.count("\n")
                i = j
                continue
        # Raw / byte strings: r"…", r#"…"#, b"…", br#"…"#.
        if c in "rb":
            j = i
            prefix = c
            if c == "b" and j + 1 < n and text[j + 1] == "r":
                prefix = "br"
                j += 1
            if prefix in ("r", "br") or (c == "b" and j + 1 < n and text[j + 1] == '"'):
                k = j + 1
                hashes = 0
                while prefix != "b" and k < n and text[k] == "#":
                    hashes += 1
                    k += 1
                if k < n and text[k] == '"' and (prefix != "b" or hashes == 0):
                    if prefix == "b":
                        # plain byte string b"…": fall through to the
                        # normal string scanner below with the b eaten
                        body, end, nl = _scan_string(text, k)
                        toks.append(Tok("str", text[i:end], line))
                        line += nl
                        i = end
                        continue
                    close = '"' + "#" * hashes
                    end = text.find(close, k + 1)
                    end = n if end == -1 else end + len(close)
                    toks.append(Tok("str", text[i:end], line))
                    line += text.count("\n", i, end)
                    i = end
                    continue
        if c == '"':
            body, end, nl = _scan_string(text, i)
            toks.append(Tok("str", text[i:end], line))
            line += nl
            i = end
            continue
        # Char literal vs lifetime.
        if c == "'":
            if i + 1 < n and text[i + 1] == "\\":
                j = i + 2
                if j < n:
                    j += 1  # escaped char (or first of \x.., \u{..})
                while j < n and text[j] != "'":
                    j += 1
                toks.append(Tok("char", text[i : j + 1], line))
                i = j + 1
                continue
            if i + 2 < n and text[i + 2] == "'" and text[i + 1] != "'":
                toks.append(Tok("char", text[i : i + 3], line))
                i += 3
                continue
            # Lifetime: 'ident (includes 'static, '_).
            j = i + 1
            while j < n and _is_ident_cont(text[j]):
                j += 1
            toks.append(Tok("lifetime", text[i:j], line))
            i = j
            continue
        if _is_ident_start(c):
            j = i + 1
            while j < n and _is_ident_cont(text[j]):
                j += 1
            toks.append(Tok("ident", text[i:j], line))
            i = j
            continue
        if c.isdigit():
            j = i + 1
            while j < n and (_is_ident_cont(text[j])):
                j += 1
            # Fraction — but not a `..` range and not a method call `.0`-style.
            if j + 1 < n and text[j] == "." and text[j + 1].isdigit():
                j += 1
                while j < n and _is_ident_cont(text[j]):
                    j += 1
            toks.append(Tok("num", text[i:j], line))
            i = j
            continue
        toks.append(Tok("punct", c, line))
        i += 1
    return toks


def _scan_string(text, i):
    """Scan a normal string starting at the opening quote `text[i]`.

    Returns (body, end_index_after_closing_quote, newlines_crossed).
    """
    j, n = i + 1, len(text)
    while j < n:
        if text[j] == "\\":
            j += 2
            continue
        if text[j] == '"':
            j += 1
            break
        j += 1
    else:
        j = n
    return text[i:j], j, text.count("\n", i, j)


def code_tokens(toks):
    """The token stream without comments (most lints want this view)."""
    return [t for t in toks if t.kind != "comment"]


def match_brace(toks, open_idx):
    """Index of the `}` matching the `{` at `open_idx` (or len(toks))."""
    depth = 0
    for k in range(open_idx, len(toks)):
        t = toks[k]
        if t.kind == "punct" and t.value == "{":
            depth += 1
        elif t.kind == "punct" and t.value == "}":
            depth -= 1
            if depth == 0:
                return k
    return len(toks)
