#!/usr/bin/env python3
"""Validate the schema of BENCH_hotpath.json.

The bench harness (`cargo bench --bench hotpath`) overwrites the file and
downstream tooling diffs its axes across commits, so schema drift — a
renamed axis, a scalar where an array of row objects is expected, a
missing acceptance note — must fail CI rather than silently break the
cross-commit diff. Content (the measured numbers) is deliberately NOT
validated: axes are allowed to be empty placeholders on machines without
a toolchain.

Usage: validate_bench_schema.py [BENCH_hotpath.json]
Exits non-zero with a message on the first schema violation.
"""

import json
import sys

# Every axis the bench writes; each must be an array of row objects.
REQUIRED_AXES = [
    "hash_width_axis",
    "probe_schedule",
    "probe_budget_axis",
    "probe_session_axis",
    "rerank_axis",
    "probe_backend_axis",
]

# Optional axes: validated when present (same row shape plus extra
# required fields), absent is fine. `degraded_axis` measures the
# deadline-degraded serving path, which only exists on builds new enough
# to carry time budgets — older BENCH files stay valid.
OPTIONAL_AXES = {
    "degraded_axis": {"deadline_us": (int, float), "degraded_pct": (int, float)},
    # `mutation_axis` measures the WAL-backed mutable-store write path:
    # acked ingest batches, recovery replay over the accumulated WAL, and
    # the tombstone filter's query overhead vs the compacted twin. Rows
    # carry `op` naming the measurement and `n_mutations` sizing it
    # (batch rows / WAL records / tombstones in the queried epoch).
    "mutation_axis": {"op": str, "n_mutations": (int, float)},
}

# Scalar fields the bench stamps alongside the axes.
REQUIRED_SCALARS = {"bench": str, "note": str, "n_items": (int, float), "dim": (int, float)}

# Fields every row of an axis must carry (all axes record timings).
REQUIRED_ROW_FIELDS = {"median_us": (int, float), "min_us": (int, float)}


def fail(msg):
    print(f"BENCH schema error: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_hotpath.json"
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: {e}")

    if not isinstance(doc, dict):
        fail(f"{path}: top level must be an object, got {type(doc).__name__}")

    for key, ty in REQUIRED_SCALARS.items():
        if key not in doc:
            fail(f"{path}: missing required field {key!r}")
        if not isinstance(doc[key], ty):
            fail(f"{path}: field {key!r} must be {ty}, got {type(doc[key]).__name__}")

    def check_axis(axis, extra_fields):
        rows = doc[axis]
        if not isinstance(rows, list):
            fail(f"{path}: axis {axis!r} must be an array, got {type(rows).__name__}")
        fields = {**REQUIRED_ROW_FIELDS, **extra_fields}
        for i, row in enumerate(rows):
            if not isinstance(row, dict):
                fail(f"{path}: {axis}[{i}] must be an object, got {type(row).__name__}")
            for field, fty in fields.items():
                if field not in row:
                    fail(f"{path}: {axis}[{i}] missing field {field!r}")
                if not isinstance(row[field], fty):
                    want = fty.__name__ if isinstance(fty, type) else "a number"
                    fail(
                        f"{path}: {axis}[{i}].{field} must be {want}, "
                        f"got {type(row[field]).__name__}"
                    )

    for axis in REQUIRED_AXES:
        if axis not in doc:
            fail(f"{path}: missing required axis {axis!r}")
        check_axis(axis, {})

    present_optional = [a for a in OPTIONAL_AXES if a in doc]
    for axis in present_optional:
        check_axis(axis, OPTIONAL_AXES[axis])

    n = len(REQUIRED_AXES) + len(present_optional)
    print(f"{path}: schema ok ({n} axes)")


if __name__ == "__main__":
    main()
