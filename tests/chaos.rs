//! Chaos suite (run with `--features fault-injection`): seeded fault
//! plans against the sharded router, asserting the degraded-serving
//! contract from README §"Failure model & degraded serving" — a faulted
//! query NEVER panics the caller and NEVER presents a silently truncated
//! top-k as complete. Every outcome must be one of:
//!
//! 1. `Ok` untagged — element-identical to the fault-free oracle;
//! 2. `Ok` tagged `ShardLoss` — element-identical to the exact merge over
//!    the shards *not* named in `lost_shards`;
//! 3. `Err` carrying a typed `ShardLossError` (quorum lost).
//!
//! Fault plans are pure functions of a seed, so any failure here replays
//! exactly; there is no flakiness to tolerate.
#![cfg(feature = "fault-injection")]

use std::sync::{Arc, Once};
use std::time::Duration;

use rangelsh::config::ServeConfig;
use rangelsh::coordinator::{
    BatchPolicy, FaultPlan, OverloadedError, QueryParams, QueryServer, RouterPolicy, SearchEngine,
    SearchResult, Shard, ShardLossError, ShardedRouter,
};
use rangelsh::data::{synthetic, Dataset};
use rangelsh::hash::NativeHasher;
use rangelsh::index::range::{RangeLshIndex, RangeLshParams};
use rangelsh::ItemId;

const DIM: usize = 8;
const N_SHARDS: usize = 3;
const PER_SHARD: usize = 200;
const TOP_K: usize = 5;

/// Injected panics go through the global panic hook before the router's
/// `catch_unwind` contains them; silence exactly those (and only those)
/// so the chaos sweep doesn't bury real failures in expected backtraces.
fn quiet_injected_panics() {
    static INSTALL: Once = Once::new();
    INSTALL.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .is_some_and(|msg| msg.contains("injected panic"));
            if !injected {
                prev(info);
            }
        }));
    });
}

/// A 3-shard router corpus: `N_SHARDS` row-slices of one long-tailed
/// dataset, each with its own exact-budget engine (so per-shard answers
/// are exact top-k over the slice and merges are analytically checkable).
fn build_shards(seed: u64) -> Vec<Shard> {
    let full = synthetic::longtail_sift(N_SHARDS * PER_SHARD, DIM, seed);
    (0..N_SHARDS)
        .map(|s| {
            let (lo, hi) = (s * PER_SHARD * DIM, (s + 1) * PER_SHARD * DIM);
            let slice = Arc::new(Dataset::from_flat(DIM, full.flat()[lo..hi].to_vec()));
            let hasher: Arc<NativeHasher> = Arc::new(NativeHasher::new(DIM, 64, seed + s as u64));
            let index = Arc::new(
                RangeLshIndex::build(&slice, hasher.as_ref(), RangeLshParams::new(16, 4)).unwrap(),
            );
            let cfg = ServeConfig { probe_budget: usize::MAX, top_k: TOP_K, ..Default::default() };
            Shard {
                engine: Arc::new(SearchEngine::new(index, slice, hasher, cfg).unwrap()),
                id_offset: (s * PER_SHARD) as ItemId,
            }
        })
        .collect()
}

/// Fault-free oracle: exact merge over every shard not in `lost`,
/// replicating the router's tie-break (score desc, then global id).
fn merged_oracle(shards: &[Shard], lost: &[usize], query: &[f32]) -> Vec<SearchResult> {
    let mut merged: Vec<SearchResult> = Vec::new();
    for (s, shard) in shards.iter().enumerate() {
        if lost.contains(&s) {
            continue;
        }
        merged.extend(
            shard
                .engine
                .search_with(query, &QueryParams::default())
                .unwrap()
                .into_iter()
                .map(|r| SearchResult { id: r.id + shard.id_offset, score: r.score }),
        );
    }
    merged.sort_by(|a, b| b.score.total_cmp(&a.score).then(a.id.cmp(&b.id)));
    merged.truncate(TOP_K);
    merged
}

#[test]
fn seeded_fault_storms_never_lie_about_completeness() {
    quiet_injected_panics();
    let policy = RouterPolicy {
        min_shards: 2,
        max_retries: 2,
        backoff_base: Duration::from_micros(1),
        backoff_cap: Duration::from_micros(10),
    };
    let (mut untagged, mut partial, mut quorum_lost) = (0usize, 0usize, 0usize);
    for seed in [11u64, 29, 47] {
        let queries = synthetic::gaussian_queries(40, DIM, seed ^ 0x77);
        for rate_pct in [10u32, 30, 60] {
            for persistence in [1u32, 2, 4] {
                // Fresh router per plan so the deterministic query index
                // restarts at 0 and the run is a pure function of
                // (seed, rate_pct, persistence).
                let shards = build_shards(seed);
                let mut router =
                    ShardedRouter::with_policy(build_shards(seed), TOP_K, policy).unwrap();
                router.set_fault_plan(Some(
                    FaultPlan::seeded(seed.wrapping_mul(101) + rate_pct as u64, rate_pct)
                        .with_persistence(persistence)
                        .with_delay(Duration::from_micros(50)),
                ));
                for qi in 0..queries.len() {
                    let q = queries.row(qi);
                    let ctx = format!("seed {seed} rate {rate_pct}% persist {persistence} q {qi}");
                    match router.query_full(q, &QueryParams::default()) {
                        Ok(resp) => match resp.degraded {
                            None => {
                                assert_eq!(
                                    resp.results,
                                    merged_oracle(&shards, &[], q),
                                    "untagged response must equal the fault-free oracle ({ctx})"
                                );
                                untagged += 1;
                            }
                            Some(tag) => {
                                assert!(
                                    !tag.lost_shards.is_empty(),
                                    "no budgets are set, so the only legal tag is \
                                     shard loss ({ctx})"
                                );
                                assert!(
                                    N_SHARDS - tag.lost_shards.len() >= policy.min_shards,
                                    "tagged response below quorum ({ctx})"
                                );
                                assert_eq!(
                                    resp.results,
                                    merged_oracle(&shards, &tag.lost_shards, q),
                                    "partial merge must equal the surviving-shard \
                                     oracle ({ctx})"
                                );
                                partial += 1;
                            }
                        },
                        Err(e) => {
                            let loss = e.downcast_ref::<ShardLossError>().unwrap_or_else(|| {
                                panic!("router error must be a typed ShardLossError ({ctx}): {e:#}")
                            });
                            assert!(loss.responded < policy.min_shards, "{ctx}");
                            assert!(!loss.failed.is_empty(), "{ctx}");
                            quorum_lost += 1;
                        }
                    }
                }
            }
        }
    }
    // Deterministic plans, so coverage assertions cannot flake: the sweep
    // must exercise the healthy path and at least one failure path.
    assert!(untagged > 0, "sweep never produced a clean answer");
    assert!(
        partial + quorum_lost > 0,
        "sweep never lost a shard — fault injection is not reaching the router"
    );
}

// ---------------------------------------------------------------------------
// Crash-point recovery: the WAL-backed mutable store (README §"Mutability &
// recovery model"). A crash is injected at each named point of the
// mutation/checkpoint protocol; reopening the directory must recover a
// state whose query answers are BIT-identical (ids and score bits) to the
// contract for that point:
//
//   PostWalAppend / PreApply  the mutation was acknowledged durable —
//                             recovery must include it;
//   MidCompaction             nothing was written — recovery is the exact
//                             pre-compaction state;
//   PreRename                 staged files exist but were never published —
//                             recovery is the exact pre-checkpoint state.

use rangelsh::coordinator::{CrashPoint, MutableConfig, MutableStore};
use rangelsh::util::tmp::TempPath;

fn store_cfg() -> ServeConfig {
    ServeConfig { probe_budget: usize::MAX, top_k: TOP_K, code_bits: 16, ..Default::default() }
}

fn new_store(dir: &std::path::Path, n: usize, seed: u64) -> MutableStore<u64> {
    MutableStore::create(
        dir,
        Arc::new(synthetic::longtail_sift(n, DIM, seed)),
        RangeLshParams::new(16, 8),
        7,
        store_cfg(),
        MutableConfig::manual(),
    )
    .unwrap()
}

fn reopen(dir: &std::path::Path) -> MutableStore<u64> {
    MutableStore::open(dir, store_cfg(), MutableConfig::manual()).unwrap()
}

/// Full-budget answers as (id, score-bits) — bit-identity, not approximate.
fn bit_answers(store: &MutableStore<u64>, queries: &Dataset) -> Vec<Vec<(ItemId, u32)>> {
    let engine = store.current();
    (0..queries.len())
        .map(|qi| {
            engine
                .search(queries.row(qi))
                .unwrap()
                .into_iter()
                .map(|r| (r.id, r.score.to_bits()))
                .collect()
        })
        .collect()
}

fn crash_plan(point: CrashPoint) -> FaultPlan {
    FaultPlan::seeded(0, 0).with_crash(point)
}

#[test]
fn acked_mutations_survive_crashes_before_apply() {
    // The WAL record is fsynced at PostWalAppend and PreApply: replay
    // must reconstruct the acknowledged mutation even though the epoch
    // swap never happened. The recovered store is compared bit-for-bit
    // against a twin that applied the same mutations without faults.
    let queries = synthetic::gaussian_queries(8, DIM, 101);
    for point in [CrashPoint::PostWalAppend, CrashPoint::PreApply] {
        let dir = TempPath::new("chaos-crash-mut");
        let twin_dir = TempPath::new("chaos-crash-mut-twin");
        let store = new_store(dir.path(), 400, 31);
        let twin = new_store(twin_dir.path(), 400, 31);

        // Crash an ingest...
        let extra = synthetic::longtail_sift(25, DIM, 32);
        store.set_fault_plan(Some(crash_plan(point)));
        let err = store.ingest(extra.flat()).unwrap_err();
        assert!(format!("{err:#}").contains("injected crash"), "{point:?}");
        drop(store);
        twin.ingest(extra.flat()).unwrap();
        let store = reopen(dir.path());
        assert_eq!(store.live_len(), twin.live_len(), "{point:?}");
        assert_eq!(bit_answers(&store, &queries), bit_answers(&twin, &queries), "{point:?}");

        // ... then a delete of the current winners, on the recovered store.
        let victims: Vec<ItemId> =
            bit_answers(&store, &queries)[0].iter().map(|&(id, _)| id).collect();
        store.set_fault_plan(Some(crash_plan(point)));
        assert!(store.delete(&victims).is_err(), "{point:?}");
        drop(store);
        twin.delete(&victims).unwrap();
        let store = reopen(dir.path());
        let recovered = bit_answers(&store, &queries);
        assert_eq!(recovered, bit_answers(&twin, &queries), "{point:?} delete");
        for row in &recovered {
            for (id, _) in row {
                assert!(!victims.contains(id), "{point:?}: tombstoned id {id} surfaced");
            }
        }
    }
}

#[test]
fn compaction_crashes_recover_the_precompaction_epoch() {
    // MidCompaction writes nothing to disk; PreRename stages fsynced
    // temp files but never publishes them. Both recover the exact
    // pre-compaction state — tombstones, answers, and all.
    let queries = synthetic::gaussian_queries(8, DIM, 102);
    for point in [CrashPoint::MidCompaction, CrashPoint::PreRename] {
        let dir = TempPath::new("chaos-crash-compact");
        let store = new_store(dir.path(), 400, 33);
        store.delete(&(0..40).collect::<Vec<ItemId>>()).unwrap();
        let want = bit_answers(&store, &queries);
        store.set_fault_plan(Some(crash_plan(point)));
        let err = store.compact().unwrap_err();
        assert!(format!("{err:#}").contains("injected crash"), "{point:?}");
        drop(store);
        let store = reopen(dir.path());
        assert_eq!(store.tombstoned_len(), 40, "{point:?}");
        assert_eq!(bit_answers(&store, &queries), want, "{point:?}");
        // The recovered store is fully live: a real compaction now
        // succeeds and preserves the answers (full budget, so dropping
        // tombstoned rows cannot change the top-k).
        store.compact().unwrap();
        assert_eq!(store.tombstoned_len(), 0, "{point:?}");
        assert_eq!(bit_answers(&store, &queries), want, "{point:?} post-compaction");
    }
}

#[test]
fn tombstoned_ids_never_surface_across_recovery_and_reopen() {
    // The visibility rule end-to-end: once a delete is acknowledged, the
    // id is invisible to full-budget queries in every recovered epoch —
    // including the epoch recovered after a crashed compaction, and a
    // second clean reopen through the width-erased `AnyStore` path.
    // (Resumed-session filtering is exercised element-for-element by the
    // property suite; this test pins the recovery surface.)
    let dir = TempPath::new("chaos-tombstone");
    let store = new_store(dir.path(), 300, 34);
    let queries = synthetic::gaussian_queries(4, DIM, 103);
    let victims: Vec<ItemId> = bit_answers(&store, &queries)[0]
        .iter()
        .map(|&(id, _)| id)
        .chain(0..10)
        .collect();
    store.delete(&victims).unwrap();
    store.set_fault_plan(Some(crash_plan(CrashPoint::PreRename)));
    assert!(store.compact().is_err());
    drop(store);

    let store = reopen(dir.path());
    assert_eq!(store.tombstoned_len(), victims.len());
    let answers = bit_answers(&store, &queries);
    for row in &answers {
        for (id, _) in row {
            assert!(!victims.contains(id), "recovered epoch surfaced tombstoned id {id}");
        }
    }
    drop(store);

    // A clean reopen through AnyStore sees the same state and the same rule.
    let any = rangelsh::coordinator::AnyStore::open(
        dir.path(),
        store_cfg(),
        MutableConfig::manual(),
    )
    .unwrap();
    assert_eq!(any.code_words(), 1);
    assert_eq!(any.tombstoned_len(), victims.len());
    let engine = any.engine();
    for (qi, want) in answers.iter().enumerate() {
        let got: Vec<(ItemId, u32)> = engine
            .search(queries.row(qi))
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.score.to_bits()))
            .collect();
        assert_eq!(&got, want, "AnyStore reopen diverged on query {qi}");
    }
}

#[test]
fn overload_shedding_is_typed_under_fault_injection_build() {
    // The server's admission control (not the router) rejects a budget
    // smaller than the batch window before enqueueing; same contract as
    // the in-crate unit test, exercised here under the feature build.
    let shard = build_shards(5).remove(0);
    let policy = BatchPolicy::new(64, Duration::from_millis(10));
    let handle = QueryServer::spawn(shard.engine.clone(), policy).unwrap();
    let queries = synthetic::gaussian_queries(1, DIM, 6);
    let params = QueryParams::new().with_time_budget(Duration::from_millis(1));
    let err = handle.query_full(queries.row(0).to_vec(), params).unwrap_err();
    let over = err
        .downcast_ref::<OverloadedError>()
        .expect("sub-window budget must shed with a typed OverloadedError");
    assert_eq!(over.queue_depth, 0);
    assert_eq!(over.time_budget, Some(Duration::from_millis(1)));
    // A budget-less query on the same handle still answers completely.
    let resp = handle.query_full(queries.row(0).to_vec(), QueryParams::default()).unwrap();
    assert!(resp.degraded.is_none());
    assert_eq!(resp.results.len(), TOP_K);
}
