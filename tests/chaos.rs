//! Chaos suite (run with `--features fault-injection`): seeded fault
//! plans against the sharded router, asserting the degraded-serving
//! contract from README §"Failure model & degraded serving" — a faulted
//! query NEVER panics the caller and NEVER presents a silently truncated
//! top-k as complete. Every outcome must be one of:
//!
//! 1. `Ok` untagged — element-identical to the fault-free oracle;
//! 2. `Ok` tagged `ShardLoss` — element-identical to the exact merge over
//!    the shards *not* named in `lost_shards`;
//! 3. `Err` carrying a typed `ShardLossError` (quorum lost).
//!
//! Fault plans are pure functions of a seed, so any failure here replays
//! exactly; there is no flakiness to tolerate.
#![cfg(feature = "fault-injection")]

use std::sync::{Arc, Once};
use std::time::Duration;

use rangelsh::config::ServeConfig;
use rangelsh::coordinator::{
    BatchPolicy, FaultPlan, OverloadedError, QueryParams, QueryServer, RouterPolicy, SearchEngine,
    SearchResult, Shard, ShardLossError, ShardedRouter,
};
use rangelsh::data::{synthetic, Dataset};
use rangelsh::hash::NativeHasher;
use rangelsh::index::range::{RangeLshIndex, RangeLshParams};
use rangelsh::ItemId;

const DIM: usize = 8;
const N_SHARDS: usize = 3;
const PER_SHARD: usize = 200;
const TOP_K: usize = 5;

/// Injected panics go through the global panic hook before the router's
/// `catch_unwind` contains them; silence exactly those (and only those)
/// so the chaos sweep doesn't bury real failures in expected backtraces.
fn quiet_injected_panics() {
    static INSTALL: Once = Once::new();
    INSTALL.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .is_some_and(|msg| msg.contains("injected panic"));
            if !injected {
                prev(info);
            }
        }));
    });
}

/// A 3-shard router corpus: `N_SHARDS` row-slices of one long-tailed
/// dataset, each with its own exact-budget engine (so per-shard answers
/// are exact top-k over the slice and merges are analytically checkable).
fn build_shards(seed: u64) -> Vec<Shard> {
    let full = synthetic::longtail_sift(N_SHARDS * PER_SHARD, DIM, seed);
    (0..N_SHARDS)
        .map(|s| {
            let (lo, hi) = (s * PER_SHARD * DIM, (s + 1) * PER_SHARD * DIM);
            let slice = Arc::new(Dataset::from_flat(DIM, full.flat()[lo..hi].to_vec()));
            let hasher: Arc<NativeHasher> = Arc::new(NativeHasher::new(DIM, 64, seed + s as u64));
            let index = Arc::new(
                RangeLshIndex::build(&slice, hasher.as_ref(), RangeLshParams::new(16, 4)).unwrap(),
            );
            let cfg = ServeConfig { probe_budget: usize::MAX, top_k: TOP_K, ..Default::default() };
            Shard {
                engine: Arc::new(SearchEngine::new(index, slice, hasher, cfg).unwrap()),
                id_offset: (s * PER_SHARD) as ItemId,
            }
        })
        .collect()
}

/// Fault-free oracle: exact merge over every shard not in `lost`,
/// replicating the router's tie-break (score desc, then global id).
fn merged_oracle(shards: &[Shard], lost: &[usize], query: &[f32]) -> Vec<SearchResult> {
    let mut merged: Vec<SearchResult> = Vec::new();
    for (s, shard) in shards.iter().enumerate() {
        if lost.contains(&s) {
            continue;
        }
        merged.extend(
            shard
                .engine
                .search_with(query, &QueryParams::default())
                .unwrap()
                .into_iter()
                .map(|r| SearchResult { id: r.id + shard.id_offset, score: r.score }),
        );
    }
    merged.sort_by(|a, b| b.score.total_cmp(&a.score).then(a.id.cmp(&b.id)));
    merged.truncate(TOP_K);
    merged
}

#[test]
fn seeded_fault_storms_never_lie_about_completeness() {
    quiet_injected_panics();
    let policy = RouterPolicy {
        min_shards: 2,
        max_retries: 2,
        backoff_base: Duration::from_micros(1),
        backoff_cap: Duration::from_micros(10),
    };
    let (mut untagged, mut partial, mut quorum_lost) = (0usize, 0usize, 0usize);
    for seed in [11u64, 29, 47] {
        let queries = synthetic::gaussian_queries(40, DIM, seed ^ 0x77);
        for rate_pct in [10u32, 30, 60] {
            for persistence in [1u32, 2, 4] {
                // Fresh router per plan so the deterministic query index
                // restarts at 0 and the run is a pure function of
                // (seed, rate_pct, persistence).
                let shards = build_shards(seed);
                let mut router =
                    ShardedRouter::with_policy(build_shards(seed), TOP_K, policy).unwrap();
                router.set_fault_plan(Some(
                    FaultPlan::seeded(seed.wrapping_mul(101) + rate_pct as u64, rate_pct)
                        .with_persistence(persistence)
                        .with_delay(Duration::from_micros(50)),
                ));
                for qi in 0..queries.len() {
                    let q = queries.row(qi);
                    let ctx = format!("seed {seed} rate {rate_pct}% persist {persistence} q {qi}");
                    match router.query_full(q, &QueryParams::default()) {
                        Ok(resp) => match resp.degraded {
                            None => {
                                assert_eq!(
                                    resp.results,
                                    merged_oracle(&shards, &[], q),
                                    "untagged response must equal the fault-free oracle ({ctx})"
                                );
                                untagged += 1;
                            }
                            Some(tag) => {
                                assert!(
                                    !tag.lost_shards.is_empty(),
                                    "no budgets are set, so the only legal tag is \
                                     shard loss ({ctx})"
                                );
                                assert!(
                                    N_SHARDS - tag.lost_shards.len() >= policy.min_shards,
                                    "tagged response below quorum ({ctx})"
                                );
                                assert_eq!(
                                    resp.results,
                                    merged_oracle(&shards, &tag.lost_shards, q),
                                    "partial merge must equal the surviving-shard \
                                     oracle ({ctx})"
                                );
                                partial += 1;
                            }
                        },
                        Err(e) => {
                            let loss = e.downcast_ref::<ShardLossError>().unwrap_or_else(|| {
                                panic!("router error must be a typed ShardLossError ({ctx}): {e:#}")
                            });
                            assert!(loss.responded < policy.min_shards, "{ctx}");
                            assert!(!loss.failed.is_empty(), "{ctx}");
                            quorum_lost += 1;
                        }
                    }
                }
            }
        }
    }
    // Deterministic plans, so coverage assertions cannot flake: the sweep
    // must exercise the healthy path and at least one failure path.
    assert!(untagged > 0, "sweep never produced a clean answer");
    assert!(
        partial + quorum_lost > 0,
        "sweep never lost a shard — fault injection is not reaching the router"
    );
}

#[test]
fn overload_shedding_is_typed_under_fault_injection_build() {
    // The server's admission control (not the router) rejects a budget
    // smaller than the batch window before enqueueing; same contract as
    // the in-crate unit test, exercised here under the feature build.
    let shard = build_shards(5).remove(0);
    let policy = BatchPolicy::new(64, Duration::from_millis(10));
    let handle = QueryServer::spawn(shard.engine.clone(), policy).unwrap();
    let queries = synthetic::gaussian_queries(1, DIM, 6);
    let params = QueryParams::new().with_time_budget(Duration::from_millis(1));
    let err = handle.query_full(queries.row(0).to_vec(), params).unwrap_err();
    let over = err
        .downcast_ref::<OverloadedError>()
        .expect("sub-window budget must shed with a typed OverloadedError");
    assert_eq!(over.queue_depth, 0);
    assert_eq!(over.time_budget, Some(Duration::from_millis(1)));
    // A budget-less query on the same handle still answers completely.
    let resp = handle.query_full(queries.row(0).to_vec(), QueryParams::default()).unwrap();
    assert!(resp.degraded.is_none());
    assert_eq!(resp.results.len(), TOP_K);
}
