//! Cross-module integration tests over the native path: dataset → index →
//! probe → recall → serving engine, plus CLI-level config parsing.

use std::sync::Arc;

use rangelsh::config::{Config, IndexAlgo, ServeConfig};
use rangelsh::coordinator::{AnyEngine, BatchPolicy, SearchEngine};
use rangelsh::data::{load_dataset, save_dataset, synthetic};
use rangelsh::eval::harness::{ground_truth, run_curve, CurveSpec};
use rangelsh::eval::recall::geometric_checkpoints;
use rangelsh::hash::NativeHasher;
use rangelsh::index::range::{RangeLshIndex, RangeLshParams};
use rangelsh::index::MipsIndex;

#[test]
fn end_to_end_native_pipeline_reaches_high_recall() {
    // Long-tail corpus, RANGE-LSH at the paper's L=32/m=32 point, probing
    // 5% of the corpus. At this deliberately small scale (20K items,
    // d=32, uncorrelated queries) the deterministic measurement is ~0.74
    // recall@10 — demand 0.7 as the floor; the Fig-2 bench exercises the
    // paper-scale operating points.
    let items = synthetic::longtail_sift(20_000, 32, 0);
    let queries = synthetic::gaussian_queries(100, 32, 1);
    let gt = ground_truth(&items, &queries, 10);
    let budget = items.len() / 20;
    let cps = geometric_checkpoints(10, budget, 4);
    let res = run_curve(
        &items,
        &queries,
        &gt,
        &cps,
        &CurveSpec::new(IndexAlgo::RangeLsh, 32, 32),
        "range",
    )
    .unwrap();
    assert!(
        res.curve.final_recall() >= 0.7,
        "recall at 5% probe budget: {}",
        res.curve.final_recall()
    );
}

#[test]
fn paper_headline_order_holds_on_longtail() {
    // Fig. 2 qualitative shape at test scale: RANGE > SIMPLE >= L2-ALSH
    // in probes-to-recall on long-tailed data.
    let items = synthetic::longtail_sift(8_000, 24, 2);
    let queries = synthetic::gaussian_queries(50, 24, 3);
    let gt = ground_truth(&items, &queries, 10);
    let cps = geometric_checkpoints(10, items.len(), 5);
    let probes = |algo, m| {
        run_curve(&items, &queries, &gt, &cps, &CurveSpec::new(algo, 16, m), "x")
            .unwrap()
            .curve
            .probes_to_reach(0.8)
            .unwrap_or(usize::MAX)
    };
    let range = probes(IndexAlgo::RangeLsh, 32);
    let simple = probes(IndexAlgo::SimpleLsh, 1);
    assert!(range < simple, "RANGE {range} !< SIMPLE {simple}");
}

#[test]
fn uniform_norm_control_range_equals_simple() {
    // §3.2: when all norms are equal RANGE-LSH degenerates gracefully —
    // percentile ranges share U_j == U, so recall curves must be close.
    let items = synthetic::uniform_norm(5_000, 16, 4);
    let queries = synthetic::gaussian_queries(50, 16, 5);
    let gt = ground_truth(&items, &queries, 10);
    let cps = geometric_checkpoints(50, items.len(), 3);
    let range = run_curve(
        &items, &queries, &gt, &cps,
        &CurveSpec::new(IndexAlgo::RangeLsh, 16, 16),
        "r",
    )
    .unwrap();
    let simple = run_curve(
        &items, &queries, &gt, &cps,
        &CurveSpec::new(IndexAlgo::SimpleLsh, 16, 1),
        "s",
    )
    .unwrap();
    // Same asymptote; mid-curve within a tolerance (different bit budgets:
    // RANGE pays 4 id bits).
    assert!((range.curve.final_recall() - simple.curve.final_recall()).abs() < 1e-9);
    let mid = cps.len() / 2;
    assert!(
        (range.curve.recalls[mid] - simple.curve.recalls[mid]).abs() < 0.25,
        "uniform-norm curves diverged: {} vs {}",
        range.curve.recalls[mid],
        simple.curve.recalls[mid]
    );
}

#[test]
fn dataset_io_round_trips_through_engine() {
    let tmp = rangelsh::util::tmp::TempPath::new("integration-rdat");
    let items = synthetic::longtail_sift(2_000, 16, 6);
    save_dataset(&items, tmp.path()).unwrap();
    let loaded = Arc::new(load_dataset(tmp.path()).unwrap());
    assert_eq!(loaded.len(), 2_000);

    let hasher: Arc<NativeHasher> = Arc::new(NativeHasher::new(16, 64, 7));
    let index = Arc::new(
        RangeLshIndex::build(&loaded, hasher.as_ref(), RangeLshParams::new(16, 8)).unwrap(),
    );
    let cfg = ServeConfig { probe_budget: 500, top_k: 5, ..Default::default() };
    let engine = SearchEngine::new(index, loaded, hasher, cfg).unwrap();
    let q = synthetic::gaussian_queries(1, 16, 8);
    let res = engine.search(q.row(0)).unwrap();
    assert_eq!(res.len(), 5);
}

#[test]
fn server_workload_preserves_per_query_results() {
    let items = Arc::new(synthetic::longtail_sift(3_000, 16, 9));
    let hasher: Arc<NativeHasher> = Arc::new(NativeHasher::new(16, 64, 10));
    let index = Arc::new(
        RangeLshIndex::build(&items, hasher.as_ref(), RangeLshParams::new(16, 8)).unwrap(),
    );
    let cfg = ServeConfig { probe_budget: 300, top_k: 5, ..Default::default() };
    let engine = Arc::new(SearchEngine::new(index, items, hasher, cfg).unwrap());
    let queries = synthetic::gaussian_queries(40, 16, 11);
    let policy = BatchPolicy::new(16, std::time::Duration::from_millis(2));
    let (results, _) =
        rangelsh::coordinator::server::drive_workload(engine.clone(), policy, &queries, 8)
            .unwrap();
    for qi in 0..queries.len() {
        assert_eq!(results[qi], engine.search(queries.row(qi)).unwrap(), "query {qi}");
    }
}

#[test]
fn range_lsh_serves_end_to_end_at_code_bits_128() {
    // Acceptance: a RANGE-LSH index with code_bits = 128 builds and
    // serves through the Engine (build → probe → exact re-rank), fully
    // monomorphized at engine-build time.
    let items = Arc::new(synthetic::longtail_sift(3_000, 16, 20));
    let cfg = ServeConfig {
        probe_budget: usize::MAX,
        top_k: 10,
        code_bits: 128,
        ..Default::default()
    };
    let engine =
        AnyEngine::build_native_range(items.clone(), RangeLshParams::new(128, 16), 21, cfg)
            .unwrap();
    assert_eq!(engine.code_words(), 2, "128-bit budget must pick the 2-word engine");
    let queries = synthetic::gaussian_queries(10, 16, 22);
    let gt = rangelsh::eval::exact_topk(&items, &queries, 10);
    for qi in 0..queries.len() {
        let res = engine.search(queries.row(qi)).unwrap();
        let ids: Vec<u32> = res.iter().map(|r| r.id).collect();
        assert_eq!(ids, gt[qi], "query {qi}: full-budget wide engine must be exact");
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score, "query {qi}: scores not descending");
        }
    }
}

#[test]
fn recall_at_l128_dominates_l64_on_longtail() {
    // Acceptance: on a synthetic long-tailed-norm dataset, doubling the
    // code budget from L=64 to L=128 (same m, same probe budgets) must
    // not lose recall — more hash bits = finer bucket ranking. Compare
    // mean recall across the checkpoint grid (stabler than any single
    // operating point) and spot-check the asymptote.
    let items = synthetic::longtail_sift(6_000, 24, 30);
    let queries = synthetic::gaussian_queries(60, 24, 31);
    let gt = ground_truth(&items, &queries, 10);
    let cps = geometric_checkpoints(20, items.len(), 4);
    let run = |bits: usize| {
        run_curve(
            &items,
            &queries,
            &gt,
            &cps,
            &CurveSpec::new(IndexAlgo::RangeLsh, bits, 16),
            format!("range L={bits}"),
        )
        .unwrap()
    };
    let l64 = run(64);
    let l128 = run(128);
    assert!((l64.curve.final_recall() - 1.0).abs() < 1e-9);
    assert!((l128.curve.final_recall() - 1.0).abs() < 1e-9);
    let mean = |r: &rangelsh::eval::ExperimentResult| {
        r.curve.recalls.iter().sum::<f64>() / r.curve.recalls.len() as f64
    };
    let (m64, m128) = (mean(&l64), mean(&l128));
    assert!(
        m128 >= m64 - 1e-9,
        "L=128 mean recall {m128:.4} fell below L=64 mean recall {m64:.4}"
    );
}

#[test]
fn config_files_in_repo_parse() {
    for f in ["configs/netflix_sim.toml", "configs/yahoo_sim.toml", "configs/imagenet_sim.toml"] {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(f);
        let cfg = Config::from_path(&path).unwrap_or_else(|e| panic!("{f}: {e:#}"));
        assert!(cfg.dataset.n_items > 0);
    }
}

#[test]
fn index_survives_pathological_datasets() {
    let hasher: NativeHasher = NativeHasher::new(4, 64, 0);
    // Single item.
    let one = synthetic::longtail_sift(1, 4, 0);
    let idx = RangeLshIndex::build(&one, &hasher, RangeLshParams::new(16, 8)).unwrap();
    let mut out = Vec::new();
    idx.probe(&[1.0, 0.0, 0.0, 0.0], usize::MAX, &mut out);
    assert_eq!(out, vec![0]);
    // All-identical items (ties everywhere).
    let same = rangelsh::data::Dataset::from_flat(4, [1.0f32, 2.0, 3.0, 4.0].repeat(100));
    let idx = RangeLshIndex::build(&same, &hasher, RangeLshParams::new(16, 8)).unwrap();
    let mut out = Vec::new();
    idx.probe(&[1.0, 0.0, 0.0, 0.0], usize::MAX, &mut out);
    assert_eq!(out.len(), 100);
}
