//! PJRT integration: the AOT Pallas artifacts executed from Rust must
//! agree with the native path. These tests require `make artifacts` to
//! have run; they are skipped (with a notice) when `artifacts/` is absent
//! so `cargo test` works on a fresh checkout.

use std::sync::Arc;

use rangelsh::data::synthetic;
use rangelsh::eval::exact_topk;
use rangelsh::hash::{Code128, Code256, CodeWord, ItemHasher, NativeHasher, Projection};
use rangelsh::runtime::{PjrtHasher, PjrtScorer, RuntimeHandle};

fn runtime() -> Option<RuntimeHandle> {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the `pjrt` feature — PJRT backend is a stub");
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts/ — run `make artifacts` first");
        return None;
    }
    Some(RuntimeHandle::load(dir).expect("artifacts exist but failed to load"))
}

/// The u64-specific tests additionally need a width-64 artifact dir
/// (one directory is compiled at exactly one width).
fn runtime_u64() -> Option<RuntimeHandle> {
    let rt = runtime()?;
    if rt.code_words() != 1 {
        eprintln!(
            "SKIP: artifacts compiled at {} code words — u64 cross-checks need --width 64",
            rt.code_words()
        );
        return None;
    }
    Some(rt)
}

/// Fraction of differing code bits between two code vectors.
fn bit_disagreement(a: &[u64], b: &[u64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let diff: u32 = a.iter().zip(b).map(|(x, y)| (x ^ y).count_ones()).sum();
    diff as f64 / (a.len() as f64 * 64.0)
}

#[test]
fn pjrt_item_codes_match_native() {
    let Some(rt) = runtime_u64() else { return };
    for dim in rt.manifest().hash_dims() {
        let proj = Arc::new(Projection::gaussian(dim + 1, 64, 7));
        let pjrt = PjrtHasher::<u64>::new(rt.clone(), proj.clone()).unwrap();
        let native: NativeHasher = NativeHasher::with_projection(proj);
        // 3000 rows: one full block + a padded tail block.
        let items = synthetic::longtail_sift(3000, dim, 1);
        let u = items.max_norm();
        let a = pjrt.hash_items(items.flat(), u).unwrap();
        let b = native.hash_items(items.flat(), u).unwrap();
        assert_eq!(a.len(), 3000);
        // f32 reassociation can flip a bit when a dot product sits within
        // an ulp of zero; bound the rate rather than demand exactness.
        let rate = bit_disagreement(&a, &b);
        assert!(rate < 1e-4, "dim {dim}: bit disagreement rate {rate}");
    }
}

#[test]
fn pjrt_query_codes_match_native() {
    let Some(rt) = runtime_u64() else { return };
    for dim in rt.manifest().hash_dims() {
        let proj = Arc::new(Projection::gaussian(dim + 1, 64, 8));
        let pjrt = PjrtHasher::<u64>::new(rt.clone(), proj.clone()).unwrap();
        let native: NativeHasher = NativeHasher::with_projection(proj);
        let queries = synthetic::gaussian_queries(500, dim, 2);
        let a = pjrt.hash_queries(queries.flat()).unwrap();
        let b = native.hash_queries(queries.flat()).unwrap();
        let rate = bit_disagreement(&a, &b);
        assert!(rate < 1e-4, "dim {dim}: bit disagreement rate {rate}");
    }
}

#[test]
fn pjrt_scorer_matches_native_ground_truth() {
    let Some(rt) = runtime_u64() else { return };
    let dim = rt.manifest().hash_dims()[0];
    let items = synthetic::longtail_sift(2500, dim, 3);
    let queries = synthetic::gaussian_queries(50, dim, 4);
    let scorer = PjrtScorer::new(rt);
    let pjrt_gt = scorer.exact_topk(&items, &queries, 10).unwrap();
    let native_gt = exact_topk(&items, &queries, 10);
    let mut agree = 0usize;
    for (a, b) in pjrt_gt.iter().zip(&native_gt) {
        agree += a.iter().filter(|id| b.contains(id)).count();
    }
    // Different summation orders can swap near-tied neighbours; demand
    // near-total agreement rather than exact id-order equality.
    let rate = agree as f64 / (queries.len() * 10) as f64;
    assert!(rate > 0.995, "top-k agreement {rate}");
}

#[test]
fn pjrt_index_build_equals_native_index_build() {
    use rangelsh::index::range::{RangeLshIndex, RangeLshParams};
    use rangelsh::index::MipsIndex;
    let Some(rt) = runtime_u64() else { return };
    let dim = rt.manifest().hash_dims()[0];
    let items = synthetic::longtail_sift(4000, dim, 5);
    let proj = Arc::new(Projection::gaussian(dim + 1, 64, 9));
    let pjrt = PjrtHasher::<u64>::new(rt, proj.clone()).unwrap();
    let native: NativeHasher = NativeHasher::with_projection(proj);
    let a = RangeLshIndex::build(&items, &pjrt, RangeLshParams::new(32, 16)).unwrap();
    let b = RangeLshIndex::build(&items, &native, RangeLshParams::new(32, 16)).unwrap();
    // Same partitioning, same panel ⇒ (near-)identical bucket structure.
    let (sa, sb) = (a.stats(), b.stats());
    assert_eq!(sa.n_partitions, sb.n_partitions);
    let bucket_drift =
        (sa.n_buckets as f64 - sb.n_buckets as f64).abs() / sb.n_buckets as f64;
    assert!(bucket_drift < 0.01, "bucket count drift {bucket_drift}");
    // Probe results for a query should be near-identical too.
    let q = synthetic::gaussian_queries(1, dim, 6);
    let (mut oa, mut ob) = (Vec::new(), Vec::new());
    a.probe(q.row(0), 500, &mut oa);
    b.probe(q.row(0), 500, &mut ob);
    // Rare borderline-bit flips move items between buckets, and the
    // budget cutoff then truncates different tails; 96% overlap is the
    // deterministic measurement with ample slack for either effect.
    let overlap = oa.iter().filter(|id| ob.contains(id)).count();
    assert!(overlap >= 480, "probe overlap {overlap}/500");
}

#[test]
fn runtime_rejects_wrong_shapes() {
    let Some(rt) = runtime_u64() else { return };
    let dim = rt.manifest().hash_dims()[0];
    // Bad block size must error, not crash.
    let err = rt.hash_items_block(dim, vec![0.0; 17], 1.0, Arc::new(vec![0.0; (dim + 1) * 64]));
    assert!(err.is_err());
    // Bad projection size must error.
    let block = vec![0.0f32; rt.manifest().item_block * dim];
    let err = rt.hash_items_block(dim, block, 1.0, Arc::new(vec![0.0; 3]));
    assert!(err.is_err());
}

#[test]
fn pjrt_hasher_rejects_uncompiled_dim() {
    let Some(rt) = runtime_u64() else { return };
    // dim 999 has no artifact.
    let proj = Arc::new(Projection::gaussian(1000, 64, 0));
    assert!(PjrtHasher::<u64>::new(rt, proj).is_err());
}

/// PJRT vs blocked-native cross-check at whatever width the artifact
/// directory was compiled at (the multi-word kernel path at 128/256).
fn check_pjrt_matches_native_wide<C: CodeWord>(rt: RuntimeHandle) {
    for dim in rt.manifest().hash_dims() {
        let width = rt.manifest().proj_width;
        let proj = Arc::new(Projection::gaussian(dim + 1, width, 11));
        let pjrt: PjrtHasher<C> = PjrtHasher::new(rt.clone(), proj.clone()).unwrap();
        let native: NativeHasher<C> = NativeHasher::with_projection(proj);
        let items = synthetic::longtail_sift(3000, dim, 12);
        let u = items.max_norm();
        let a = pjrt.hash_items(items.flat(), u).unwrap();
        let b = native.hash_items(items.flat(), u).unwrap();
        assert_eq!(a.len(), 3000);
        let diff: u32 = a
            .iter()
            .zip(&b)
            .map(|(x, y)| x.hamming(*y))
            .sum();
        let rate = diff as f64 / (a.len() as f64 * width as f64);
        assert!(rate < 1e-4, "dim {dim} width {width}: bit disagreement rate {rate}");
        let queries = synthetic::gaussian_queries(500, dim, 13);
        let a = pjrt.hash_queries(queries.flat()).unwrap();
        let b = native.hash_queries(queries.flat()).unwrap();
        let diff: u32 = a.iter().zip(&b).map(|(x, y)| x.hamming(*y)).sum();
        let rate = diff as f64 / (a.len() as f64 * width as f64);
        assert!(rate < 1e-4, "dim {dim} width {width} queries: rate {rate}");
    }
}

#[test]
fn pjrt_multiword_codes_match_native_at_artifact_width() {
    let Some(rt) = runtime() else { return };
    match rt.code_words() {
        1 => check_pjrt_matches_native_wide::<u64>(rt),
        2 => check_pjrt_matches_native_wide::<Code128>(rt),
        _ => check_pjrt_matches_native_wide::<Code256>(rt),
    }
}

#[test]
fn pjrt_hasher_rejects_mismatched_code_words() {
    // A width-64 dir must refuse to feed a Code128 engine and vice
    // versa — the code_words key is what AnyEngine's selection trusts.
    let Some(rt) = runtime() else { return };
    let dim = rt.manifest().hash_dims()[0];
    let proj = Arc::new(Projection::gaussian(dim + 1, rt.manifest().proj_width, 0));
    if rt.code_words() == 1 {
        assert!(PjrtHasher::<Code128>::new(rt, proj).is_err());
    } else {
        assert!(PjrtHasher::<u64>::new(rt, proj).is_err());
    }
}
