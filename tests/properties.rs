//! Property-based tests (in-tree harness, offline build): randomised
//! invariants over the coordinator's core data structures — the proptest
//! role, driven by the seeded xoshiro RNG in `rangelsh::util::rng`.

use rangelsh::data::{synthetic, Dataset};
use rangelsh::hash::codes::{partition_id_bits, widen};
use rangelsh::hash::{
    hamming, mask_bits, matches, Code128, Code256, CodeWord, ItemHasher, NativeHasher,
};
use rangelsh::index::metric::{s_hat, MetricOrder};
use rangelsh::index::range::{RangeLshIndex, RangeLshParams};
use rangelsh::index::simple::{SimpleLshIndex, SimpleLshParams};
use rangelsh::index::{partition, BucketTable, CodeProbe, MipsIndex, PartitionScheme, Prober};
use rangelsh::theory::g_rho;
use rangelsh::util::rng::Rng;
use rangelsh::ItemId;

/// Run `body` over `cases` seeded cases; report the failing seed.
fn forall(cases: u64, body: impl Fn(&mut Rng, u64)) {
    for seed in 0..cases {
        let mut rng = Rng::seed_from_u64(0xBEEF ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        body(&mut rng, seed);
    }
}

#[test]
fn prop_hamming_is_a_metric() {
    forall(200, |rng, seed| {
        let (a, b, c) = (rng.next_u64(), rng.next_u64(), rng.next_u64());
        assert_eq!(hamming(a, a), 0, "seed {seed}");
        assert_eq!(hamming(a, b), hamming(b, a), "seed {seed}");
        assert!(
            hamming(a, c) <= hamming(a, b) + hamming(b, c),
            "triangle inequality, seed {seed}"
        );
    });
}

#[test]
fn prop_matches_plus_hamming_is_bits() {
    forall(200, |rng, seed| {
        let bits = 1 + rng.gen_index(64);
        let mask = mask_bits(bits);
        let (a, b) = (rng.next_u64() & mask, rng.next_u64() & mask);
        assert_eq!(
            matches(a, b, bits) + hamming(a, b),
            bits as u32,
            "seed {seed} bits {bits}"
        );
    });
}

#[test]
fn prop_partition_is_exact_cover() {
    forall(30, |rng, seed| {
        let n = 1 + rng.gen_index(400);
        let m = 1 + rng.gen_index(40);
        let dim = 2 + rng.gen_index(10);
        let d = synthetic::longtail_sift(n, dim, seed);
        for scheme in [PartitionScheme::Percentile, PartitionScheme::UniformRange] {
            let parts = partition(&d, m, scheme).unwrap();
            let mut seen = vec![false; n];
            for p in &parts {
                assert!(!p.ids.is_empty(), "empty partition, seed {seed}");
                for &id in &p.ids {
                    assert!(!seen[id as usize], "duplicate, seed {seed} {scheme:?}");
                    seen[id as usize] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "missing item, seed {seed} {scheme:?}");
        }
    });
}

#[test]
fn prop_partition_ranges_are_norm_sorted() {
    forall(30, |rng, seed| {
        let n = 10 + rng.gen_index(300);
        let m = 1 + rng.gen_index(16);
        let d = synthetic::longtail_sift(n, 4, seed);
        for scheme in [PartitionScheme::Percentile, PartitionScheme::UniformRange] {
            let parts = partition(&d, m, scheme).unwrap();
            for w in parts.windows(2) {
                assert!(
                    w[0].u_max <= w[1].u_min + 1e-6,
                    "ranges out of order, seed {seed} {scheme:?}"
                );
            }
        }
    });
}

#[test]
fn prop_metric_order_is_total_and_descending() {
    forall(50, |rng, seed| {
        let m = 1 + rng.gen_index(20);
        let bits = 1 + rng.gen_index(40);
        let eps = (rng.uniform01() * 0.9) as f32;
        let us: Vec<f32> = (0..m).map(|_| rng.uniform(0.01, 2.0) as f32).collect();
        let order = MetricOrder::build(&us, bits, eps);
        assert_eq!(order.len(), m * (bits + 1), "seed {seed}");
        let vals: Vec<f32> = order
            .entries()
            .iter()
            .map(|&(j, l)| s_hat(us[j as usize], l, bits, eps))
            .collect();
        for w in vals.windows(2) {
            assert!(w[0] >= w[1], "not descending, seed {seed}");
        }
    });
}

#[test]
fn prop_probe_emits_each_item_exactly_once() {
    forall(15, |rng, seed| {
        let n = 50 + rng.gen_index(500);
        let dim = 4 + rng.gen_index(12);
        let bits = 8 + rng.gen_index(24);
        let m = 1 + rng.gen_index(8);
        let d = synthetic::longtail_sift(n, dim, seed);
        let h: NativeHasher = NativeHasher::new(dim, 64, seed ^ 0xFACE);
        let idx = RangeLshIndex::build(&d, &h, RangeLshParams::new(bits.max(8), m)).unwrap();
        let q = synthetic::gaussian_queries(1, dim, seed ^ 0xBEE);
        let mut out = Vec::new();
        idx.probe(q.row(0), usize::MAX, &mut out);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), n, "seed {seed}: dup or missing items");
        assert_eq!(out.len(), n, "seed {seed}");
    });
}

#[test]
fn prop_probe_budget_is_exact_when_feasible() {
    forall(15, |rng, seed| {
        let n = 100 + rng.gen_index(400);
        let budget = 1 + rng.gen_index(n);
        let d = synthetic::longtail_sift(n, 8, seed);
        let h: NativeHasher = NativeHasher::new(8, 64, seed);
        let idx = SimpleLshIndex::build(&d, &h, SimpleLshParams::new(16)).unwrap();
        let q = synthetic::gaussian_queries(1, 8, seed ^ 1);
        let mut out = Vec::new();
        idx.probe(q.row(0), budget, &mut out);
        assert_eq!(out.len(), budget, "seed {seed}");
    });
}

#[test]
fn prop_bucket_table_partitions_items_by_masked_code() {
    forall(50, |rng, seed| {
        let n = 1 + rng.gen_index(300);
        let bits = 1 + rng.gen_index(30);
        let codes: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let t = BucketTable::build(&codes, None, bits);
        assert_eq!(t.n_items(), n);
        let total: usize = t.buckets().map(|(_, items)| items.len()).sum();
        assert_eq!(total, n, "seed {seed}");
        let mask = mask_bits(bits);
        for (code, items) in t.buckets() {
            for &id in items {
                assert_eq!(codes[id as usize] & mask, code, "seed {seed}");
            }
        }
    });
}

#[test]
fn prop_recall_curves_are_monotone() {
    forall(8, |rng, seed| {
        let n = 300 + rng.gen_index(700);
        let d = synthetic::longtail_sift(n, 8, seed);
        let q = synthetic::gaussian_queries(10, 8, seed ^ 2);
        let gt = rangelsh::eval::exact_topk(&d, &q, 5);
        let h: NativeHasher = NativeHasher::new(8, 64, seed ^ 3);
        let m = 1 + rng.gen_index(8);
        let idx = RangeLshIndex::build(&d, &h, RangeLshParams::new(16, m)).unwrap();
        let cps = rangelsh::eval::recall::geometric_checkpoints(5, n, 4);
        let curve = rangelsh::eval::recall_curve(&idx, &q, &gt, &cps);
        for w in curve.recalls.windows(2) {
            assert!(w[1] >= w[0] - 1e-12, "seed {seed}: recall decreased");
        }
        assert!((curve.final_recall() - 1.0).abs() < 1e-9, "seed {seed}");
    });
}

#[test]
fn prop_g_rho_monotonicity() {
    forall(100, |rng, seed| {
        let c = rng.uniform(0.05, 0.95);
        let s0 = rng.uniform(0.05, 0.95);
        let s0_bigger = (s0 + rng.uniform(0.001, 1.0 - s0 - 1e-9)).min(1.0);
        let r1 = g_rho(c, s0);
        let r2 = g_rho(c, s0_bigger);
        assert!((0.0..=1.0).contains(&r1), "seed {seed}");
        assert!(r2 <= r1 + 1e-12, "seed {seed}: rho must decrease in S0");
    });
}

#[test]
fn prop_query_hash_scale_invariance() {
    forall(50, |rng, seed| {
        let dim = 2 + rng.gen_index(20);
        let h: NativeHasher = NativeHasher::new(dim, 64, seed);
        let q: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
        let scale = rng.uniform(0.001, 1000.0) as f32;
        let q2: Vec<f32> = q.iter().map(|v| v * scale).collect();
        assert_eq!(
            h.hash_queries(&q).unwrap(),
            h.hash_queries(&q2).unwrap(),
            "seed {seed}: query hash must be scale-invariant"
        );
    });
}

/// Wide/scalar agreement: zero-extending random `u64` codes into
/// `[u64; W]` must leave `hamming`, `matches`, and masking unchanged.
fn check_widened_agrees<C: CodeWord>(rng: &mut Rng, seed: u64) {
    let bits = 1 + rng.gen_index(64);
    let m = mask_bits(bits);
    let (a, b) = (rng.next_u64() & m, rng.next_u64() & m);
    let (wa, wb): (C, C) = (widen(a), widen(b));
    assert_eq!(wa.hamming(wb), hamming(a, b), "seed {seed} bits {bits}");
    assert_eq!(wa.matches(wb, bits), matches(a, b, bits), "seed {seed} bits {bits}");
    assert_eq!(
        wa.masked(bits),
        widen::<C>(a & mask_bits(bits)),
        "seed {seed} bits {bits}: masking disagrees with scalar path"
    );
    // The mask itself carries exactly `bits` ones, scalar or wide.
    assert_eq!(C::mask(bits).count_ones() as usize, bits, "seed {seed}");
}

#[test]
fn prop_wide_codes_agree_with_scalar_when_high_words_zero() {
    forall(300, |rng, seed| {
        check_widened_agrees::<Code128>(rng, seed);
        check_widened_agrees::<Code256>(rng, seed);
    });
}

#[test]
fn prop_wide_hamming_is_a_metric() {
    forall(200, |rng, seed| {
        let rand_code = |rng: &mut Rng| -> Code256 {
            [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64()]
        };
        let (a, b, c) = (rand_code(rng), rand_code(rng), rand_code(rng));
        assert_eq!(a.hamming(a), 0, "seed {seed}");
        assert_eq!(a.hamming(b), b.hamming(a), "seed {seed}");
        assert!(
            a.hamming(c) <= a.hamming(b) + b.hamming(c),
            "triangle inequality, seed {seed}"
        );
        // matches + hamming == bits holds across the whole wide range.
        let bits = 1 + rng.gen_index(256);
        let (am, bm) = (a.masked(bits), b.masked(bits));
        assert_eq!(am.matches(bm, bits) + am.hamming(bm), bits as u32, "seed {seed} bits {bits}");
    });
}

#[test]
fn prop_partition_id_bits_accounting_is_width_independent() {
    use rangelsh::index::range::RangeLshParams;
    forall(200, |rng, seed| {
        let m = 1 + rng.gen_index(300);
        let id_bits = partition_id_bits(m);
        // Enough bits to address m partitions, minimally so.
        assert!(1usize << id_bits >= m, "seed {seed}: 2^{id_bits} < {m}");
        assert!(id_bits == 0 || (1usize << (id_bits - 1)) < m, "seed {seed}: not minimal");
        // The per-range budget L - ceil(log2 m) is the same arithmetic at
        // every code width; only the ceiling moves.
        for total_bits in [64usize, 128, 256] {
            let params = RangeLshParams::new(total_bits, m);
            assert_eq!(
                params.hash_bits(),
                total_bits.saturating_sub(id_bits),
                "seed {seed} L={total_bits} m={m}"
            );
        }
    });
}

#[test]
fn prop_wide_bucket_tables_mirror_scalar_tables() {
    use rangelsh::index::{BucketTable, SortScratch};
    forall(30, |rng, seed| {
        let n = 1 + rng.gen_index(300);
        let bits = 1 + rng.gen_index(30);
        let codes: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let wide: Vec<Code128> = codes.iter().map(|&c| widen(c)).collect();
        let ts = BucketTable::build(&codes, None, bits);
        let tw = BucketTable::build(&wide, None, bits);
        assert_eq!(ts.n_buckets(), tw.n_buckets(), "seed {seed}");
        assert_eq!(ts.largest_bucket(), tw.largest_bucket(), "seed {seed}");
        let q = rng.next_u64();
        let (mut ss, mut sw) = (SortScratch::default(), SortScratch::default());
        ts.counting_sort_by_matches(q, &mut ss);
        tw.counting_sort_by_matches(widen(q), &mut sw);
        assert_eq!(ss.levels, sw.levels, "seed {seed}");
        assert_eq!(ss.order, sw.order, "seed {seed}");
        // Exact lookups agree too.
        let probe = codes[rng.gen_index(n)];
        assert_eq!(ts.exact(probe), tw.exact(widen(probe)), "seed {seed}");
    });
}

#[test]
fn prop_wide_native_hasher_extends_scalar_bit_convention() {
    use std::sync::Arc;
    forall(20, |rng, seed| {
        let dim = 2 + rng.gen_index(12);
        let proj = Arc::new(rangelsh::hash::Projection::gaussian(dim + 1, 64, seed));
        let scalar: NativeHasher = NativeHasher::with_projection(proj.clone());
        let wide: NativeHasher<Code256> = NativeHasher::with_projection(proj);
        let q: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
        let s = scalar.hash_queries(&q).unwrap()[0];
        let w = wide.hash_queries(&q).unwrap()[0];
        assert_eq!(w, widen::<Code256>(s), "seed {seed}: wide code must zero-extend scalar");
    });
}

/// Blocked == per-item, bit for bit: the equivalence contract of the
/// blocked bulk-hashing path, across random shapes at one width.
fn check_blocked_hash_equals_per_item<C: CodeWord>(rng: &mut Rng, seed: u64, width: usize) {
    let dim = 2 + rng.gen_index(12);
    let n = 1 + rng.gen_index(200);
    let h: NativeHasher<C> = NativeHasher::new(dim, width, seed ^ width as u64);
    let d = synthetic::longtail_sift(n, dim, seed ^ 0x5EED);
    let u = d.max_norm();
    assert_eq!(
        h.hash_items_blocked(d.flat(), u).unwrap(),
        h.hash_items_unblocked(d.flat(), u).unwrap(),
        "seed {seed} width {width} n {n}: blocked items diverge"
    );
    let q = synthetic::gaussian_queries(n, dim, seed ^ 0xF00D);
    assert_eq!(
        h.hash_queries_blocked(q.flat()).unwrap(),
        h.hash_queries_unblocked(q.flat()).unwrap(),
        "seed {seed} width {width} n {n}: blocked queries diverge"
    );
}

#[test]
fn prop_blocked_hashing_bitwise_equals_per_item_oracle() {
    forall(10, |rng, seed| {
        check_blocked_hash_equals_per_item::<u64>(rng, seed, 64);
        check_blocked_hash_equals_per_item::<Code128>(rng, seed, 128);
        check_blocked_hash_equals_per_item::<Code256>(rng, seed, 256);
    });
}

/// An [`ItemHasher`] forced onto the per-item oracle paths — used to
/// prove an index built through the default (blocked) path is identical
/// to one built item-at-a-time.
struct UnblockedHasher<C: CodeWord>(NativeHasher<C>);

impl<C: CodeWord> ItemHasher<C> for UnblockedHasher<C> {
    fn projection(&self) -> &std::sync::Arc<rangelsh::hash::Projection> {
        self.0.projection()
    }

    fn hash_items(&self, rows: &[f32], u: f32) -> rangelsh::Result<Vec<C>> {
        self.0.hash_items_unblocked(rows, u)
    }

    fn hash_queries(&self, rows: &[f32]) -> rangelsh::Result<Vec<C>> {
        self.0.hash_queries_unblocked(rows)
    }
}

/// Index-level stream equivalence for the blocked hash path: RANGE-LSH
/// built through the blocked default must probe the identical candidate
/// stream as one built through the per-item oracle, at every budget.
fn check_blocked_built_index_streams_equal<C: CodeWord>(
    d: &Dataset,
    q: &Dataset,
    params: RangeLshParams,
    seed: u64,
    m: usize,
    width: usize,
) {
    use std::sync::Arc;
    let proj = Arc::new(rangelsh::hash::Projection::gaussian(d.dim() + 1, width, seed));
    let blocked: NativeHasher<C> = NativeHasher::with_projection(proj.clone());
    let per_item = UnblockedHasher::<C>(NativeHasher::with_projection(proj));
    let a = RangeLshIndex::build(d, &blocked, params).unwrap();
    let b = RangeLshIndex::build(d, &per_item, params).unwrap();
    for qi in 0..q.len() {
        let qcode = a.hash_query(q.row(qi));
        for budget in [1usize, 7, d.len() / 2, usize::MAX] {
            let (mut oa, mut ob) = (Vec::new(), Vec::new());
            a.probe_with_code(qcode, budget, &mut oa);
            b.probe_with_code(qcode, budget, &mut ob);
            assert_eq!(oa, ob, "seed {seed} m {m} width {width} budget {budget}");
        }
    }
}

#[test]
fn prop_blocked_built_range_index_equals_per_item_built() {
    forall(3, |rng, seed| {
        let n = 200 + rng.gen_index(300);
        let d = synthetic::longtail_sift(n, 8, seed ^ 0xB10C);
        let q = synthetic::gaussian_queries(2, 8, seed ^ 0xD00D);
        for &m in &[1usize, 8, 32] {
            let p64 = RangeLshParams::new(16, m);
            check_blocked_built_index_streams_equal::<u64>(&d, &q, p64, seed, m, 64);
            let p128 = RangeLshParams::new(128, m);
            check_blocked_built_index_streams_equal::<Code128>(
                &d,
                &q,
                p128,
                seed,
                m,
                p128.hash_bits(),
            );
            let p256 = RangeLshParams::new(256, m);
            check_blocked_built_index_streams_equal::<Code256>(
                &d,
                &q,
                p256,
                seed,
                m,
                p256.hash_bits(),
            );
        }
    });
}

/// Lazy/partial probing must emit the *identical* candidate sequence the
/// eager all-ranges sort emits, element for element, at every budget —
/// the equivalence contract of the budget-adaptive probe refactor.
fn check_lazy_stream_equals_eager<C: CodeWord>(
    idx: &RangeLshIndex<C>,
    q: &Dataset,
    n: usize,
    seed: u64,
    m: usize,
) {
    for qi in 0..q.len() {
        let qcode = idx.hash_query(q.row(qi));
        for budget in [1usize, 7, n / 2, usize::MAX] {
            let (mut lazy, mut eager) = (Vec::new(), Vec::new());
            idx.probe_with_code(qcode, budget, &mut lazy);
            idx.probe_with_code_eager(qcode, budget, &mut eager);
            assert_eq!(
                lazy, eager,
                "seed {seed} m {m} q {qi} budget {budget}: lazy/eager streams diverge"
            );
        }
    }
}

#[test]
fn prop_lazy_probe_stream_equals_eager_stream() {
    forall(5, |rng, seed| {
        for &m in &[1usize, 8, 32] {
            let n = 300 + rng.gen_index(500);
            let d = synthetic::longtail_sift(n, 8, seed ^ (m as u64) << 32);
            let q = synthetic::gaussian_queries(2, 8, seed ^ 0xABC);
            // u64 at the paper's L=16 operating point...
            let p64 = RangeLshParams::new(16, m);
            let h64: NativeHasher = NativeHasher::new(8, p64.hash_bits(), seed);
            let idx64 = RangeLshIndex::build(&d, &h64, p64).unwrap();
            check_lazy_stream_equals_eager(&idx64, &q, n, seed, m);
            // ... and the wide regime the CodeWord refactor opened.
            let p128 = RangeLshParams::new(128, m);
            let h128: NativeHasher<Code128> = NativeHasher::new(8, p128.hash_bits(), seed);
            let idx128 = RangeLshIndex::build(&d, &h128, p128).unwrap();
            check_lazy_stream_equals_eager(&idx128, &q, n, seed, m);
        }
    });
}

/// Session/stream equivalence — the resumable-probing contract: for any
/// split of a budget into two `extend` calls, the concatenated stream is
/// identical, element for element, to one one-shot `probe` with the
/// summed budget.
fn check_session_stream_equals_oneshot(
    index: &dyn MipsIndex,
    query: &[f32],
    n: usize,
    ctx: &str,
) {
    let budgets = [1usize, 7, n / 2, usize::MAX];
    for &b1 in &budgets {
        for &b2 in &budgets {
            let mut oneshot = Vec::new();
            index.probe(query, b1.saturating_add(b2), &mut oneshot);
            let mut streamed = Vec::new();
            let mut session = index.prober(query);
            let got1 = session.extend(b1, &mut streamed);
            assert_eq!(got1, b1.min(n), "{ctx} b1={b1}: first extend length");
            let got2 = session.extend(b2, &mut streamed);
            assert_eq!(got1 + got2, streamed.len(), "{ctx} b1={b1} b2={b2}");
            assert_eq!(streamed, oneshot, "{ctx} b1={b1} b2={b2}: streams diverge");
            if session.is_exhausted() {
                assert_eq!(streamed.len(), n, "{ctx} b1={b1} b2={b2}: exhausted early");
            } else if streamed.len() == n {
                // Exact-fit budget: exhaustion is discovered by the next
                // extend, which must return zero ids.
                let mut extra = Vec::new();
                assert_eq!(session.extend(1, &mut extra), 0, "{ctx} b1={b1} b2={b2}");
                assert!(session.is_exhausted(), "{ctx} b1={b1} b2={b2}");
            }
        }
    }
}

#[test]
fn prop_session_stream_equals_oneshot_for_every_index_type() {
    use rangelsh::index::l2alsh::{L2AlshIndex, L2AlshParams};
    use rangelsh::index::multitable::{simple_multitable, MultiTableIndex};
    use rangelsh::index::ranged_l2alsh::{RangedL2AlshIndex, RangedL2AlshParams};
    use rangelsh::index::sign_alsh::{SignAlshIndex, SignAlshParams};
    forall(3, |rng, seed| {
        let n = 300 + rng.gen_index(300);
        let d = synthetic::longtail_sift(n, 8, seed);
        let q = synthetic::gaussian_queries(2, 8, seed ^ 0xC0DE);
        // Builds are query-independent: construct every index once per
        // seed, then sweep the budget-split matrix per query.
        // RANGE-LSH at u64 and Code128, m in {1, 8, 32}.
        let mut ranges: Vec<(String, Box<dyn MipsIndex>)> = Vec::new();
        for &m in &[1usize, 8, 32] {
            let p64 = RangeLshParams::new(16, m);
            let h64: NativeHasher = NativeHasher::new(8, p64.hash_bits(), seed);
            ranges.push((
                format!("range64 m={m}"),
                Box::new(RangeLshIndex::build(&d, &h64, p64).unwrap()),
            ));
            let p128 = RangeLshParams::new(128, m);
            let h128: NativeHasher<Code128> = NativeHasher::new(8, p128.hash_bits(), seed);
            ranges.push((
                format!("range128 m={m}"),
                Box::new(RangeLshIndex::build(&d, &h128, p128).unwrap()),
            ));
        }
        let hs: NativeHasher = NativeHasher::new(8, 64, seed ^ 1);
        let simple = SimpleLshIndex::build(&d, &hs, SimpleLshParams::new(16)).unwrap();
        let hw: NativeHasher<Code128> = NativeHasher::new(8, 128, seed ^ 2);
        let simple_w = SimpleLshIndex::build(&d, &hw, SimpleLshParams::new(96)).unwrap();
        let sign: SignAlshIndex =
            SignAlshIndex::build(&d, SignAlshParams::recommended(16)).unwrap();
        let l2 = L2AlshIndex::build(&d, L2AlshParams::recommended(8)).unwrap();
        let rl2 = RangedL2AlshIndex::build(&d, RangedL2AlshParams::recommended(8, 4)).unwrap();
        let mt = MultiTableIndex(simple_multitable(&d, 10, 3).unwrap());
        for qi in 0..q.len() {
            let query = q.row(qi);
            for (ctx, idx) in &ranges {
                check_session_stream_equals_oneshot(idx.as_ref(), query, n, ctx);
            }
            check_session_stream_equals_oneshot(&simple, query, n, "simple64");
            check_session_stream_equals_oneshot(&simple_w, query, n, "simple128");
            check_session_stream_equals_oneshot(&sign, query, n, "sign_alsh");
            check_session_stream_equals_oneshot(&l2, query, n, "l2_alsh");
            check_session_stream_equals_oneshot(&rl2, query, n, "ranged_l2_alsh");
            let mut union = Vec::new();
            mt.probe(query, usize::MAX, &mut union);
            check_session_stream_equals_oneshot(&mt, query, union.len(), "multitable");
        }
    });
}

#[test]
fn prop_code_session_stream_equals_code_oneshot() {
    // The precomputed-code twin: CodeProbe::prober_with_code against
    // probe_with_code, RANGE + SIMPLE, u64 + Code128.
    forall(4, |rng, seed| {
        let n = 200 + rng.gen_index(300);
        let d = synthetic::longtail_sift(n, 8, seed);
        let q = synthetic::gaussian_queries(1, 8, seed ^ 0xFACE);
        let p = RangeLshParams::new(16, 8);
        let h: NativeHasher = NativeHasher::new(8, p.hash_bits(), seed);
        let range = RangeLshIndex::build(&d, &h, p).unwrap();
        let hs: NativeHasher<Code128> = NativeHasher::new(8, 128, seed ^ 3);
        let simple = SimpleLshIndex::build(&d, &hs, SimpleLshParams::new(128)).unwrap();
        let budgets = [1usize, 7, n / 2, usize::MAX];
        for &b1 in &budgets {
            for &b2 in &budgets {
                let qc = range.hash_query(q.row(0));
                let mut oneshot = Vec::new();
                range.probe_with_code(qc, b1.saturating_add(b2), &mut oneshot);
                let mut streamed = Vec::new();
                let mut session = range.prober_with_code(qc);
                session.extend(b1, &mut streamed);
                session.extend(b2, &mut streamed);
                assert_eq!(streamed, oneshot, "seed {seed} range b1={b1} b2={b2}");

                let qc = simple.hash_query(q.row(0));
                let mut oneshot = Vec::new();
                simple.probe_with_code(qc, b1.saturating_add(b2), &mut oneshot);
                let mut streamed = Vec::new();
                let mut session = simple.prober_with_code(qc);
                session.extend(b1, &mut streamed);
                session.extend(b2, &mut streamed);
                assert_eq!(streamed, oneshot, "seed {seed} simple b1={b1} b2={b2}");
            }
        }
    });
}

/// MIH vs counting sort, RANGE-LSH: with the chunk tables attached the
/// index must emit the *identical* candidate stream (tie order pinned as
/// exact, element for element), one-shot and through resumable sessions,
/// at every budget. The two indexes share a hasher seed, so any
/// divergence is the candidate-generation backend's fault alone.
fn check_mih_stream_equals_counting_sort<C: CodeWord>(
    d: &Dataset,
    q: &Dataset,
    code_bits: usize,
    m: usize,
    seed: u64,
) {
    let params = RangeLshParams::new(code_bits, m);
    let h: NativeHasher<C> = NativeHasher::new(d.dim(), params.hash_bits(), seed);
    let oracle_idx = RangeLshIndex::build(d, &h, params).unwrap();
    let mut mih_idx = RangeLshIndex::build(d, &h, params).unwrap();
    mih_idx.enable_mih();
    let n = d.len();
    let budgets = [1usize, 7, n / 2, usize::MAX];
    for qi in 0..q.len() {
        let qcode = oracle_idx.hash_query(q.row(qi));
        for &budget in &budgets {
            let (mut oracle, mut mih) = (Vec::new(), Vec::new());
            oracle_idx.probe_with_code(qcode, budget, &mut oracle);
            mih_idx.probe_with_code(qcode, budget, &mut mih);
            assert_eq!(
                mih, oracle,
                "seed {seed} L={code_bits} m={m} q {qi} budget {budget}: streams diverge"
            );
        }
        // Any two-way budget split through an MIH session concatenates to
        // the counting-sort one-shot with the summed budget — including
        // splits that force the below-floor re-sort on resume.
        for &b1 in &budgets {
            for &b2 in &budgets {
                let mut oracle = Vec::new();
                oracle_idx.probe_with_code(qcode, b1.saturating_add(b2), &mut oracle);
                let mut streamed = Vec::new();
                let mut session = mih_idx.prober_with_code(qcode);
                session.extend(b1, &mut streamed);
                session.extend(b2, &mut streamed);
                assert_eq!(
                    streamed, oracle,
                    "seed {seed} L={code_bits} m={m} q {qi} b1={b1} b2={b2}: session diverges"
                );
            }
        }
    }
}

#[test]
fn prop_mih_probe_stream_equals_counting_sort_oracle() {
    forall(3, |rng, seed| {
        let n = 300 + rng.gen_index(300);
        let d = synthetic::longtail_sift(n, 8, seed ^ 0x314);
        let q = synthetic::gaussian_queries(2, 8, seed ^ 0x159);
        for &m in &[1usize, 8, 32] {
            check_mih_stream_equals_counting_sort::<u64>(&d, &q, 16, m, seed);
            check_mih_stream_equals_counting_sort::<Code128>(&d, &q, 128, m, seed);
            check_mih_stream_equals_counting_sort::<Code256>(&d, &q, 256, m, seed);
        }
    });
}

/// The SIMPLE-LSH twin of [`check_mih_stream_equals_counting_sort`]: the
/// single-table probe + session paths through the chunk tables.
fn check_simple_mih_stream_equals_counting_sort<C: CodeWord>(
    d: &Dataset,
    q: &Dataset,
    code_bits: usize,
    width: usize,
    seed: u64,
) {
    let h: NativeHasher<C> = NativeHasher::new(d.dim(), width, seed);
    let oracle_idx = SimpleLshIndex::build(d, &h, SimpleLshParams::new(code_bits)).unwrap();
    let mut mih_idx = SimpleLshIndex::build(d, &h, SimpleLshParams::new(code_bits)).unwrap();
    mih_idx.enable_mih();
    let n = d.len();
    let budgets = [1usize, 7, n / 2, usize::MAX];
    for qi in 0..q.len() {
        let qcode = oracle_idx.hash_query(q.row(qi));
        for &budget in &budgets {
            let (mut oracle, mut mih) = (Vec::new(), Vec::new());
            oracle_idx.probe_with_code(qcode, budget, &mut oracle);
            mih_idx.probe_with_code(qcode, budget, &mut mih);
            assert_eq!(
                mih, oracle,
                "seed {seed} simple L={code_bits} q {qi} budget {budget}: streams diverge"
            );
        }
        for &b1 in &budgets {
            for &b2 in &budgets {
                let mut oracle = Vec::new();
                oracle_idx.probe_with_code(qcode, b1.saturating_add(b2), &mut oracle);
                let mut streamed = Vec::new();
                let mut session = mih_idx.prober_with_code(qcode);
                session.extend(b1, &mut streamed);
                session.extend(b2, &mut streamed);
                assert_eq!(
                    streamed, oracle,
                    "seed {seed} simple L={code_bits} q {qi} b1={b1} b2={b2}: session diverges"
                );
            }
        }
    }
}

#[test]
fn prop_mih_simple_stream_equals_counting_sort_oracle() {
    forall(3, |rng, seed| {
        let n = 200 + rng.gen_index(300);
        let d = synthetic::longtail_sift(n, 8, seed ^ 0x265);
        let q = synthetic::gaussian_queries(2, 8, seed ^ 0x358);
        check_simple_mih_stream_equals_counting_sort::<u64>(&d, &q, 24, 64, seed);
        check_simple_mih_stream_equals_counting_sort::<Code128>(&d, &q, 96, 128, seed);
        check_simple_mih_stream_equals_counting_sort::<Code256>(&d, &q, 200, 256, seed);
    });
}

#[test]
fn prop_simple_partial_probe_matches_full_sort_reference() {
    forall(10, |rng, seed| {
        let n = 100 + rng.gen_index(400);
        let d = synthetic::longtail_sift(n, 8, seed);
        let h: NativeHasher = NativeHasher::new(8, 64, seed ^ 5);
        let idx = SimpleLshIndex::build(&d, &h, SimpleLshParams::new(16)).unwrap();
        let q = synthetic::gaussian_queries(1, 8, seed ^ 6);
        let qcode = idx.hash_query(q.row(0));
        // Eager reference: full grouping, Hamming-ranked walk.
        let mut groups = Vec::new();
        idx.table().group_by_matches(qcode, &mut groups);
        let mut reference: Vec<ItemId> = Vec::new();
        for l in (0..groups.len()).rev() {
            for bucket in &groups[l] {
                reference.extend_from_slice(bucket);
            }
        }
        assert_eq!(reference.len(), n, "seed {seed}");
        for budget in [1usize, 7, n / 2, usize::MAX] {
            let mut out = Vec::new();
            idx.probe_with_code(qcode, budget, &mut out);
            assert_eq!(
                out[..],
                reference[..budget.min(n)],
                "seed {seed} budget {budget}: partial probe diverges from full sort"
            );
        }
    });
}

#[test]
fn prop_batched_probe_equals_per_query_probes() {
    forall(8, |rng, seed| {
        let n = 200 + rng.gen_index(400);
        let b = 1 + rng.gen_index(7);
        let budget = 1 + rng.gen_index(n);
        let d = synthetic::longtail_sift(n, 8, seed);
        let h: NativeHasher = NativeHasher::new(8, 64, seed ^ 9);
        let idx = SimpleLshIndex::build(&d, &h, SimpleLshParams::new(20)).unwrap();
        let q = synthetic::gaussian_queries(b, 8, seed ^ 10);
        let qcodes: Vec<u64> = (0..b).map(|i| idx.hash_query(q.row(i))).collect();
        let mut batched: Vec<Vec<ItemId>> = vec![Vec::new(); b];
        idx.probe_batch_with_codes(&qcodes, budget, &mut batched);
        for (qi, &qcode) in qcodes.iter().enumerate() {
            let mut single = Vec::new();
            idx.probe_with_code(qcode, budget, &mut single);
            assert_eq!(batched[qi], single, "seed {seed} q {qi} budget {budget}");
        }
    });
}

/// Pruned streaming re-rank vs the exhaustive oracle, element for element
/// (ids **and** bit-exact scores) — the equivalence contract of the fused
/// probe/re-rank path: the Cauchy–Schwarz admission test and the
/// whole-query `‖q‖·U_j` early-out may only skip work, never change an
/// answer.
fn check_streaming_rerank_equals_exhaustive<C: CodeWord>(
    d: &std::sync::Arc<Dataset>,
    queries: &[Vec<f32>],
    code_bits: usize,
    m: usize,
    seed: u64,
) {
    use rangelsh::config::{QueryParams, RerankMode, ServeConfig};
    use rangelsh::coordinator::SearchEngine;
    use std::sync::Arc;
    let params = RangeLshParams::new(code_bits, m);
    let h: Arc<NativeHasher<C>> =
        Arc::new(NativeHasher::new(d.dim(), params.hash_bits(), seed));
    let idx: Arc<RangeLshIndex<C>> =
        Arc::new(RangeLshIndex::build(d, h.as_ref(), params).unwrap());
    let cfg = ServeConfig { probe_budget: usize::MAX, top_k: 1, ..Default::default() };
    let streaming: SearchEngine<C> =
        SearchEngine::new(idx.clone(), d.clone(), h.clone(), cfg.clone()).unwrap();
    let cfg = ServeConfig { rerank: RerankMode::Exhaustive, ..cfg };
    let oracle: SearchEngine<C> = SearchEngine::new(idx, d.clone(), h, cfg).unwrap();
    let n = d.len();
    for (qi, q) in queries.iter().enumerate() {
        for &k in &[1usize, 10, n] {
            for &budget in &[k, n / 2, usize::MAX] {
                let p = QueryParams::new().with_top_k(k).with_probe_budget(budget);
                let got = streaming.search_with(q, &p).unwrap();
                let want = oracle.search_with(q, &p).unwrap();
                let ctx = format!("seed {seed} L={code_bits} m={m} q={qi} k={k} b={budget}");
                assert_eq!(got.len(), want.len(), "{ctx}: lengths");
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(g.id, w.id, "{ctx} position {i}: ids diverge");
                    assert_eq!(
                        g.score.to_bits(),
                        w.score.to_bits(),
                        "{ctx} position {i}: score bits diverge"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_streaming_pruned_rerank_equals_exhaustive_oracle() {
    use std::sync::Arc;
    forall(2, |rng, seed| {
        let n = 200 + rng.gen_index(100);
        let base = synthetic::longtail_sift(n, 8, seed);
        // Tie-heavy twin: every row duplicated, so scores tie exactly and
        // membership hangs on the ascending-id tie-break.
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for i in 0..n {
            rows.push(base.row(i).to_vec());
            rows.push(base.row(i).to_vec());
        }
        let dup = Arc::new(Dataset::from_rows(&rows));
        let base = Arc::new(base);
        let q = synthetic::gaussian_queries(2, 8, seed ^ 0x51);
        let mut queries: Vec<Vec<f32>> = (0..q.len()).map(|i| q.row(i).to_vec()).collect();
        // ‖q‖ = 0: every bound is zero; nothing may be pruned away.
        queries.push(vec![0.0; 8]);
        for &m in &[1usize, 8, 32] {
            check_streaming_rerank_equals_exhaustive::<u64>(&base, &queries, 16, m, seed);
            check_streaming_rerank_equals_exhaustive::<Code128>(&base, &queries, 128, m, seed);
            check_streaming_rerank_equals_exhaustive::<Code256>(&base, &queries, 256, m, seed);
            // The tie-heavy dataset at the scalar width per m keeps the
            // matrix (and runtime) bounded; width does not interact with
            // the re-rank tie-break, only the probe order feeding it.
            check_streaming_rerank_equals_exhaustive::<u64>(&dup, &queries, 16, m, seed);
        }
    });
}

// ---------------------------------------------------------------------------
// Online mutability (README §"Mutability & recovery model"): a store
// mutated through the WAL-backed product path, and an index extended
// in place, must be indistinguishable — answer for answer, id for id,
// score bit for score bit — from structures freshly rebuilt over only
// the live rows.

/// Interleaved insert/delete/query schedule through `MutableStore`. At
/// every checkpoint the store's full-budget answers are compared element
/// for element against a `SearchEngine` freshly built over only the live
/// rows with the exhaustive re-rank oracle — local oracle ids mapped back
/// through the monotone live-id list, scores compared bit for bit. A
/// final compaction (the drift-repair step) must leave answers unmoved.
fn check_mutated_store_equals_rebuilt<C>(
    rng: &mut Rng,
    seed: u64,
    code_bits: usize,
    backend: rangelsh::config::ProbeBackend,
) where
    C: rangelsh::coordinator::store::StoredWidth,
{
    use rangelsh::config::{RerankMode, ServeConfig};
    use rangelsh::coordinator::{MutableConfig, MutableStore, SearchEngine};
    use rangelsh::util::tmp::TempPath;
    use std::sync::Arc;

    const DIM: usize = 8;
    let n0 = 120 + rng.gen_index(80);
    let params = RangeLshParams::new(code_bits, 8);
    let cfg = ServeConfig {
        probe_budget: usize::MAX,
        top_k: 5,
        code_bits,
        probe_backend: backend,
        ..Default::default()
    };
    let dir = TempPath::new("prop-mutable");
    let base = synthetic::longtail_sift(n0, DIM, seed ^ 0xA11CE);
    let mut rows: Vec<f32> = base.flat().to_vec();
    let mut dead: Vec<bool> = vec![false; n0];
    let store = MutableStore::<C>::create(
        dir.path(),
        Arc::new(base),
        params,
        seed ^ 0x5EED,
        cfg.clone(),
        MutableConfig::manual(),
    )
    .unwrap();
    let queries = synthetic::gaussian_queries(2, DIM, seed ^ 0xDA7A);

    let check = |rows: &[f32], dead: &[bool], ctx: &str| {
        let mut idmap: Vec<ItemId> = Vec::new();
        let mut flat: Vec<f32> = Vec::new();
        for (i, &gone) in dead.iter().enumerate() {
            if !gone {
                idmap.push(i as ItemId);
                flat.extend_from_slice(&rows[i * DIM..(i + 1) * DIM]);
            }
        }
        let live = Arc::new(Dataset::from_flat(DIM, flat));
        let width = if code_bits <= 64 { 64 } else { params.hash_bits() };
        let h: Arc<NativeHasher<C>> = Arc::new(NativeHasher::new(DIM, width, seed ^ 0x0C));
        let idx = Arc::new(RangeLshIndex::build(&live, h.as_ref(), params).unwrap());
        let ocfg = ServeConfig { rerank: RerankMode::Exhaustive, ..cfg.clone() };
        let oracle: SearchEngine<C> = SearchEngine::new(idx, live, h, ocfg).unwrap();
        let engine = store.current();
        for qi in 0..queries.len() {
            let got: Vec<(ItemId, u32)> = engine
                .search(queries.row(qi))
                .unwrap()
                .into_iter()
                .map(|r| (r.id, r.score.to_bits()))
                .collect();
            let want: Vec<(ItemId, u32)> = oracle
                .search(queries.row(qi))
                .unwrap()
                .into_iter()
                .map(|r| (idmap[r.id as usize], r.score.to_bits()))
                .collect();
            assert_eq!(got, want, "seed {seed} L={code_bits} {backend:?} {ctx} q{qi}");
        }
    };

    check(&rows, &dead, "initial");
    for round in 0u64..3 {
        // Ingest a fresh batch (acked ids must be dense and sequential)...
        let extra = synthetic::longtail_sift(10 + rng.gen_index(20), DIM, seed ^ (round + 1));
        let ids = store.ingest(extra.flat()).unwrap();
        assert_eq!(ids[0] as usize, dead.len(), "seed {seed} round {round}: ids not dense");
        assert_eq!(ids.len(), extra.len(), "seed {seed} round {round}");
        rows.extend_from_slice(extra.flat());
        dead.extend(std::iter::repeat(false).take(extra.len()));
        // ...then tombstone a random live subset (old and new ids alike).
        let live_ids: Vec<ItemId> = (0..dead.len() as ItemId)
            .filter(|&id| !dead[id as usize])
            .collect();
        let mut victims: Vec<ItemId> =
            (0..8).map(|_| live_ids[rng.gen_index(live_ids.len())]).collect();
        victims.sort_unstable();
        victims.dedup();
        store.delete(&victims).unwrap();
        for &id in &victims {
            dead[id as usize] = true;
        }
        check(&rows, &dead, &format!("round {round}"));
    }
    store.compact().unwrap();
    assert_eq!(store.tombstoned_len(), 0, "seed {seed}: compaction left tombstones");
    check(&rows, &dead, "post-compaction");
}

#[test]
fn prop_mutated_store_answers_equal_freshly_rebuilt_oracle() {
    use rangelsh::config::ProbeBackend;
    forall(2, |rng, seed| {
        for backend in [ProbeBackend::CountingSort, ProbeBackend::Mih] {
            check_mutated_store_equals_rebuilt::<u64>(rng, seed, 16, backend);
            check_mutated_store_equals_rebuilt::<Code128>(rng, seed, 128, backend);
            check_mutated_store_equals_rebuilt::<Code256>(rng, seed, 256, backend);
        }
    });
}

/// Tombstone-filtered resumable sessions over an in-place-extended index:
/// any two-way budget split concatenates to the one-shot stream with the
/// summed budget; no tombstoned id ever appears; the exhausted stream is
/// exactly the live id set, each id once. Inserts run first so the
/// fill-gap session contract is exercised on a *mutated* index (touched
/// ranges rebuilt, untouched ranges shared from the previous epoch).
fn check_tombstone_session_contract<C: CodeWord>(
    rng: &mut Rng,
    seed: u64,
    code_bits: usize,
    mih: bool,
) {
    use rangelsh::index::mutable::{insert_into_index, Tombstones, TombstonedIndex};
    use std::sync::Arc;

    const DIM: usize = 8;
    let n0 = 150 + rng.gen_index(100);
    let extra = 30 + rng.gen_index(30);
    let all = synthetic::longtail_sift(n0 + extra, DIM, seed ^ 0x70B);
    let base = Dataset::from_flat(DIM, all.flat()[..n0 * DIM].to_vec());
    let params = RangeLshParams::new(code_bits, 8);
    let width = if code_bits <= 64 { 64 } else { params.hash_bits() };
    let h: NativeHasher<C> = NativeHasher::new(DIM, width, seed ^ 0x11);
    let built = RangeLshIndex::build(&base, &h, params).unwrap();
    let new_ids: Vec<ItemId> = (n0 as ItemId..(n0 + extra) as ItemId).collect();
    let mut grown = insert_into_index(&built, &all, &new_ids).unwrap();
    if mih {
        grown.enable_mih();
    }
    let n = n0 + extra;
    let mut tombs = Tombstones::new();
    for _ in 0..n / 8 {
        tombs.set(rng.gen_index(n) as ItemId);
    }
    let live_n = n - tombs.len();
    let view = TombstonedIndex::new(Arc::new(grown), Arc::new(tombs));
    let q = synthetic::gaussian_queries(2, DIM, seed ^ 0x99);
    let budgets = [1usize, 7, live_n / 2, usize::MAX];
    for qi in 0..q.len() {
        let ctx = format!("seed {seed} L={code_bits} mih={mih} q{qi}");
        let qcode = view.inner().hash_query(q.row(qi));
        // Exhausted one-shot == the live set, each id exactly once.
        let mut full = Vec::new();
        view.probe_with_code(qcode, usize::MAX, &mut full);
        assert_eq!(full.len(), live_n, "{ctx}: stream length");
        let mut sorted = full.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), live_n, "{ctx}: duplicate ids in stream");
        for &id in &full {
            assert!(!view.tombstones().contains(id), "{ctx}: tombstoned id {id} surfaced");
        }
        for &b1 in &budgets {
            for &b2 in &budgets {
                let mut oneshot = Vec::new();
                view.probe_with_code(qcode, b1.saturating_add(b2), &mut oneshot);
                let mut streamed = Vec::new();
                let mut session = view.session(qcode);
                let got1 = session.extend(b1, &mut streamed);
                assert_eq!(got1, b1.min(live_n), "{ctx} b1={b1}: first extend length");
                let got2 = session.extend(b2, &mut streamed);
                assert_eq!(got1 + got2, streamed.len(), "{ctx} b1={b1} b2={b2}");
                assert_eq!(streamed, oneshot, "{ctx} b1={b1} b2={b2}: streams diverge");
            }
        }
    }
}

#[test]
fn prop_tombstone_sessions_equal_oneshot_and_never_leak() {
    forall(3, |rng, seed| {
        for mih in [false, true] {
            check_tombstone_session_contract::<u64>(rng, seed, 16, mih);
            check_tombstone_session_contract::<Code128>(rng, seed, 128, mih);
            check_tombstone_session_contract::<Code256>(rng, seed, 256, mih);
        }
    });
}

#[test]
fn prop_engine_results_sorted_and_exact() {
    use rangelsh::config::ServeConfig;
    use rangelsh::coordinator::SearchEngine;
    use std::sync::Arc;
    forall(8, |rng, seed| {
        let n = 200 + rng.gen_index(800);
        let d: Arc<Dataset> = Arc::new(synthetic::longtail_sift(n, 8, seed));
        let h: Arc<NativeHasher> = Arc::new(NativeHasher::new(8, 64, seed));
        let idx =
            Arc::new(RangeLshIndex::build(&d, h.as_ref(), RangeLshParams::new(16, 4)).unwrap());
        let k = 1 + rng.gen_index(10);
        let cfg = ServeConfig { probe_budget: n, top_k: k, ..Default::default() };
        let engine = SearchEngine::new(idx, d.clone(), h, cfg).unwrap();
        let q = synthetic::gaussian_queries(1, 8, seed ^ 4);
        let res = engine.search(q.row(0)).unwrap();
        assert_eq!(res.len(), k.min(n), "seed {seed}");
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score, "seed {seed}: unsorted results");
        }
        // Full-budget engine == exact top-k.
        let gt = rangelsh::eval::exact_topk(&d, &q, k);
        let ids: Vec<u32> = res.iter().map(|r| r.id).collect();
        assert_eq!(ids, gt[0], "seed {seed}");
    });
}
