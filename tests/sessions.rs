//! Resumable query-session edge cases (in-tree harness, offline build):
//! the `Prober` contract around zero-budget extends, index exhaustion,
//! and `ProbeStats` accumulation across `extend` calls — the behaviors a
//! serving layer leans on when it streams candidates adaptively.

use rangelsh::config::{QueryParams, ServeConfig};
use rangelsh::coordinator::SearchEngine;
use rangelsh::data::synthetic;
use rangelsh::hash::NativeHasher;
use rangelsh::index::range::{RangeLshIndex, RangeLshParams};
use rangelsh::index::simple::{SimpleLshIndex, SimpleLshParams};
use rangelsh::index::{CodeProbe, MipsIndex, Prober};
use rangelsh::ItemId;
use std::sync::Arc;

fn range_index(n: usize, bits: usize, m: usize, seed: u64) -> RangeLshIndex {
    let d = synthetic::longtail_sift(n, 8, seed);
    let h: NativeHasher = NativeHasher::new(8, 64, seed ^ 0xAB);
    RangeLshIndex::build(&d, &h, RangeLshParams::new(bits, m)).unwrap()
}

#[test]
fn extend_zero_is_a_true_noop() {
    let idx = range_index(500, 16, 8, 1);
    let d_queries = synthetic::gaussian_queries(1, 8, 2);
    let qcode = idx.hash_query(d_queries.row(0));
    let mut session = idx.session(qcode);
    let mut out = Vec::new();
    // Zero-budget extends emit nothing and do no sorting work at all.
    for _ in 0..3 {
        assert_eq!(session.extend(0, &mut out), 0);
    }
    assert!(out.is_empty());
    assert_eq!(session.stats().ranges_sorted, 0, "extend(0) must not sort");
    assert_eq!(session.stats().items_emitted, 0);
    assert!(!session.is_exhausted());
    // ... and the session still works normally afterwards.
    assert_eq!(session.extend(10, &mut out), 10);
    assert_eq!(out.len(), 10);
}

#[test]
fn exhaustion_returns_fewer_exactly_once_then_zero() {
    let n = 400;
    let idx = range_index(n, 16, 8, 3);
    let q = synthetic::gaussian_queries(1, 8, 4);
    let mut session = idx.prober(q.row(0));
    let mut out = Vec::new();
    assert_eq!(session.extend(n - 3, &mut out), n - 3);
    assert!(!session.is_exhausted());
    // The overshooting extend returns the 3 leftovers — fewer than asked,
    // exactly once...
    assert_eq!(session.extend(100, &mut out), 3);
    assert!(session.is_exhausted());
    assert_eq!(out.len(), n);
    // ... and every later extend returns zero without touching `out`.
    for _ in 0..3 {
        assert_eq!(session.extend(100, &mut out), 0);
    }
    assert_eq!(out.len(), n);
    // The emitted set is the full corpus, each id once.
    let mut sorted = out.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), n);
}

#[test]
fn probe_stats_accumulate_across_extends() {
    let n = 2000;
    let idx = range_index(n, 16, 32, 5);
    let q = synthetic::gaussian_queries(1, 8, 6);
    let qcode = idx.hash_query(q.row(0));
    let mut session = idx.session(qcode);
    let mut out = Vec::new();
    let mut prev_sorted = 0usize;
    let mut emitted = 0usize;
    for step in [1usize, 9, 40, 450, 1500, 100] {
        emitted += session.extend(step, &mut out);
        let stats = session.stats();
        assert_eq!(stats.items_emitted, emitted, "after step {step}");
        assert_eq!(stats.items_emitted, out.len(), "after step {step}");
        assert!(
            stats.ranges_sorted >= prev_sorted,
            "ranges_sorted must be monotone across extends"
        );
        prev_sorted = stats.ranges_sorted;
    }
    assert!(session.is_exhausted() || emitted == out.len());
    // Fully drained: every range was sorted exactly once (never twice —
    // re-materialization is counted separately in ranges_resorted).
    session.extend(usize::MAX, &mut out);
    let stats = session.stats();
    assert_eq!(stats.items_emitted, n);
    assert_eq!(stats.ranges_sorted, 32);
    // One-shot comparison: same stream as a fresh exhaustive probe.
    let mut oneshot = Vec::new();
    idx.probe_with_code(qcode, usize::MAX, &mut oneshot);
    assert_eq!(out, oneshot);
}

#[test]
fn simple_lsh_session_stats_accumulate() {
    let d = synthetic::longtail_sift(300, 8, 7);
    let h: NativeHasher = NativeHasher::new(8, 64, 8);
    let idx = SimpleLshIndex::build(&d, &h, SimpleLshParams::new(16)).unwrap();
    let q = synthetic::gaussian_queries(1, 8, 9);
    let mut session = idx.prober(q.row(0));
    let mut out = Vec::new();
    session.extend(5, &mut out);
    assert_eq!(session.stats().items_emitted, 5);
    assert_eq!(session.stats().ranges_sorted, 1, "one table, one sort");
    session.extend(295, &mut out);
    let stats = session.stats();
    assert_eq!(stats.items_emitted, 300);
    assert_eq!(stats.ranges_sorted, 1, "resume must not count a new sort");
    assert!(session.is_exhausted() || out.len() == 300);
}

#[test]
fn engine_sessions_respect_per_request_params_end_to_end() {
    // The full stack: QueryParams resolved against ServeConfig, probing
    // through sessions, exact re-rank — chunked extends with an
    // exhaustive target must reproduce the exact top-k.
    let d = Arc::new(synthetic::longtail_sift(1000, 8, 10));
    let h: Arc<NativeHasher> = Arc::new(NativeHasher::new(8, 64, 11));
    let idx = Arc::new(RangeLshIndex::build(&d, h.as_ref(), RangeLshParams::new(16, 8)).unwrap());
    let cfg = ServeConfig { probe_budget: 100, top_k: 5, ..Default::default() };
    let engine = SearchEngine::new(idx, d.clone(), h, cfg).unwrap();
    let q = synthetic::gaussian_queries(4, 8, 12);
    let gt = rangelsh::eval::exact_topk(&d, &q, 5);
    let exhaustive = QueryParams::new()
        .with_probe_budget(usize::MAX)
        .with_min_candidates(usize::MAX)
        .with_extend_step(64);
    for qi in 0..q.len() {
        let res = engine.search_with(q.row(qi), &exhaustive).unwrap();
        let ids: Vec<ItemId> = res.iter().map(|r| r.id).collect();
        assert_eq!(ids, gt[qi], "query {qi}");
    }
}
